//! Shared helpers for the paper-table benches. Each bench target includes
//! this via `#[path = "common.rs"] mod common;`.
//!
//! Benches run on scaled-down models and synthetic datasets (DESIGN.md
//! §Substitutions); the printed tables put the paper's reported numbers
//! next to ours so the *shape* of each result can be compared directly.

#![allow(dead_code)]

use spa::coordinator::{
    train_prune, train_prune_finetune, NoFinetuneAlgo, PipelineCfg, PipelineReport,
};
use spa::criteria::Criterion;
use spa::data::ImageDataset;
use spa::obspa::CalibSource;
use spa::prune::Scope;
use spa::train::TrainCfg;
use spa::zoo::ImageCfg;

/// True when `SPA_BENCH_SMOKE=1`: every paper-table bench runs one tiny
/// configuration (2 training steps, first experiment row only) so CI can
/// *execute* each bench binary, not just compile it.
pub fn smoke() -> bool {
    std::env::var("SPA_BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// Scale a training-step count down to a smoke-sized run.
pub fn steps(full: usize) -> usize {
    if smoke() {
        2
    } else {
        full
    }
}

/// Keep only the first experiment configuration in smoke mode.
pub fn take_smoke<T>(v: Vec<T>) -> Vec<T> {
    if smoke() {
        v.into_iter().take(1).collect()
    } else {
        v
    }
}

/// Measured-iteration count for micro benches (1 in smoke mode).
pub fn iters(full: usize) -> usize {
    if smoke() {
        1
    } else {
        full
    }
}

/// Warmup-iteration count for micro benches (0 in smoke mode).
pub fn warmup(full: usize) -> usize {
    if smoke() {
        0
    } else {
        full
    }
}

/// Standard bench-scale image config (SynthCIFAR).
pub fn cifar_cfg(classes: usize) -> ImageCfg {
    ImageCfg {
        channels: 3,
        hw: 8,
        classes,
        batch: 8,
    }
}

/// SynthCIFAR-10 / -100 stand-ins (100 classes scaled to 20 to keep the
/// classifier head in proportion to the mini models).
pub fn synth_cifar10(seed: u64) -> ImageDataset {
    ImageDataset::synth_cifar(10, 1024, 8, 3, seed)
}

pub fn synth_cifar100(seed: u64) -> ImageDataset {
    ImageDataset::synth_cifar(20, 1024, 8, 3, seed)
}

/// "SynthImageNet": more classes, larger train set (mini regime).
pub fn synth_imagenet(seed: u64) -> ImageDataset {
    ImageDataset::synth_cifar(20, 1536, 8, 3, seed)
}

/// Bench-scale pipeline config (smoke-aware step counts).
pub fn bench_pipeline(criterion: Criterion, scope: Scope, target_rf: f64) -> PipelineCfg {
    PipelineCfg {
        criterion: criterion.into(),
        scope,
        target_rf,
        train: TrainCfg {
            steps: steps(120),
            lr: 0.05,
            log_every: 0,
            ..Default::default()
        },
        finetune: TrainCfg {
            steps: steps(60),
            lr: 0.02,
            log_every: 0,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// One train-prune-finetune run returning the report.
pub fn tpf(
    model: spa::ir::Graph,
    ds: &ImageDataset,
    criterion: Criterion,
    scope: Scope,
    target_rf: f64,
    iterations: usize,
) -> PipelineReport {
    let mut cfg = bench_pipeline(criterion, scope, target_rf);
    cfg.iterations = iterations;
    train_prune_finetune(model, ds, &cfg).expect("tpf pipeline").1
}

/// One no-finetune run (OBSPA or DFPC) on an ALREADY TRAINED model clone.
pub fn no_finetune(
    trained: spa::ir::Graph,
    ds: &ImageDataset,
    ood: Option<&ImageDataset>,
    algo: NoFinetuneAlgo,
    target_rf: f64,
) -> PipelineReport {
    // reuse the pipeline but skip (re)training by setting steps = 0
    let mut cfg = bench_pipeline(Criterion::L1, Scope::FullCc, target_rf);
    cfg.train.steps = 0;
    train_prune(trained, ds, ood, algo, target_rf, &cfg)
        .expect("no-finetune pipeline")
        .1
}

/// Train a base model once (for sharing across no-finetune methods).
pub fn train_base(mut g: spa::ir::Graph, ds: &ImageDataset, full_steps: usize) -> spa::ir::Graph {
    spa::train::train(
        &mut g,
        ds,
        &TrainCfg {
            steps: steps(full_steps),
            lr: 0.05,
            log_every: 0,
            ..Default::default()
        },
    )
    .expect("base training");
    g
}

pub fn pct(x: f32) -> String {
    format!("{:.2}%", x * 100.0)
}

pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// Convenient names for the OBSPA calibration variants.
pub const OBSPA_ID: NoFinetuneAlgo = NoFinetuneAlgo::Obspa(CalibSource::InDistribution);
pub const OBSPA_OOD: NoFinetuneAlgo = NoFinetuneAlgo::Obspa(CalibSource::OutOfDistribution);
pub const OBSPA_DF: NoFinetuneAlgo = NoFinetuneAlgo::Obspa(CalibSource::DataFree);
pub const DFPC: NoFinetuneAlgo = NoFinetuneAlgo::Dfpc;
