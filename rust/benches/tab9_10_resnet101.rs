//! Paper Tabs. 9 & 10 — ResNet-101, CIFAR-10/100 without fine-tuning
//! (plus the base-model accuracies of Tab. 11).

#[path = "common.rs"]
mod common;

use spa::coordinator::NoFinetuneAlgo;
use spa::train;
use spa::util::Table;
use spa::zoo;

fn main() {
    let mut t = Table::new(
        "Tabs. 9/10 — resnet101-mini without fine-tuning",
        &["dataset", "method", "base acc.", "acc. drop", "RF", "RP", "paper drop / RF"],
    );
    let paper: &[(&str, &[(&str, &str)])] = &[
        ("CIFAR-10", &[
            ("DFPC", "-4.95% / 1.64x"),
            ("OBSPA (ID)", "-0.93% / 1.59x"),
            ("OBSPA (OOD)", "-1.08% / 1.59x"),
            ("OBSPA (DataFree)", "-1.51% / 1.58x"),
        ]),
        ("CIFAR-100", &[
            ("DFPC", "-9.40% / 1.72x"),
            ("OBSPA (ID)", "-7.31% / 1.68x"),
            ("OBSPA (OOD)", "-6.68% / 1.68x"),
            ("OBSPA (DataFree)", "-9.95% / 1.61x"),
        ]),
    ];
    for (dsname, rows) in common::take_smoke(paper.to_vec()) {
        let (ds, ood) = if dsname == "CIFAR-10" {
            (common::synth_cifar10(91), common::synth_cifar100(92))
        } else {
            (common::synth_cifar100(93), common::synth_cifar10(94))
        };
        let g0 = zoo::resnet101(common::cifar_cfg(ds.classes), 19);
        let base = common::train_base(g0, &ds, 220);
        let base_acc = train::evaluate(&base, &ds, 256).unwrap();
        let algos: [(&str, NoFinetuneAlgo); 4] = [
            ("DFPC", common::DFPC),
            ("OBSPA (ID)", common::OBSPA_ID),
            ("OBSPA (OOD)", common::OBSPA_OOD),
            ("OBSPA (DataFree)", common::OBSPA_DF),
        ];
        for (i, (name, algo)) in algos.into_iter().enumerate() {
            let rep = common::no_finetune(base.clone(), &ds, Some(&ood), algo, 1.5);
            t.row(&[
                dsname.to_string(),
                name.to_string(),
                common::pct(base_acc),
                format!("{:+.2}%", (rep.final_acc - base_acc) * 100.0),
                common::ratio(rep.rf),
                common::ratio(rep.rp),
                rows[i].1.to_string(),
            ]);
        }
    }
    t.print();
    println!("shape to check: OBSPA beats DFPC on both datasets; base accs = Tab. 11 analog");
}
