//! Microbench: the `util::par` worker pool on the three hot paths —
//! GEMM/conv, the OBSPA native kernels, and per-group importance scoring
//! — timed at 1 vs `SPA_THREADS` (default 4) workers, with a bitwise
//! equality check on every pair of results.

#[path = "common.rs"]
mod common;

use common::smoke;
use spa::criteria::{Criterion, Saliency};
use spa::prune::{score_groups, Agg, Norm};
use spa::runtime::kernels as rk;
use spa::tensor::{ops, Tensor};
use spa::util::{bench, par, Rng, Table};
use spa::zoo;
use spa::{Session, Target};

fn main() {
    // Multi-thread column honors an SPA_THREADS pin; when the pool would
    // be single-threaded anyway, measure at 4 workers so the comparison
    // is meaningful.
    let threads = match par::max_threads() {
        t if t >= 2 => t,
        _ => 4,
    };
    let iters = common::iters(5);
    let warmup = common::warmup(1);
    let title = format!("micro — worker pool speedup (1 vs {threads} threads)");
    let multi_header = format!("{threads} threads (ms)");
    let mut t = Table::new(
        &title,
        &["workload", "1 thread (ms)", multi_header.as_str(), "speedup", "bits"],
    );
    let mut rng = Rng::new(7);

    let gemm_n = if smoke() { 96 } else { 384 };
    let a = Tensor::new(vec![gemm_n, gemm_n], rng.uniform_vec(gemm_n * gemm_n, -1.0, 1.0));
    let b = Tensor::new(vec![gemm_n, gemm_n], rng.uniform_vec(gemm_n * gemm_n, -1.0, 1.0));
    let s1 = bench("gemm/1t", warmup, iters, || {
        par::with_threads(1, || {
            let _ = ops::matmul(&a, &b);
        });
    });
    let sn = bench(&format!("gemm/{threads}t"), warmup, iters, || {
        par::with_threads(threads, || {
            let _ = ops::matmul(&a, &b);
        });
    });
    let y1 = par::with_threads(1, || ops::matmul(&a, &b));
    let yn = par::with_threads(threads, || ops::matmul(&a, &b));
    push_row(&mut t, &format!("gemm {gemm_n}^3"), &s1, &sn, &y1, &yn);

    let imgs = if smoke() { 4 } else { 32 };
    let conv_label = format!("conv2d b{imgs}");
    let x = Tensor::new(vec![imgs, 16, 16, 16], rng.uniform_vec(imgs * 16 * 256, -1.0, 1.0));
    let w = Tensor::new(vec![32, 16, 3, 3], rng.uniform_vec(32 * 16 * 9, -0.3, 0.3));
    let s1 = bench("conv2d/1t", warmup, iters, || {
        par::with_threads(1, || {
            let _ = ops::conv2d(&x, &w, None, 1, 1, 1);
        });
    });
    let sn = bench(&format!("conv2d/{threads}t"), warmup, iters, || {
        par::with_threads(threads, || {
            let _ = ops::conv2d(&x, &w, None, 1, 1, 1);
        });
    });
    let y1 = par::with_threads(1, || ops::conv2d(&x, &w, None, 1, 1, 1));
    let yn = par::with_threads(threads, || ops::conv2d(&x, &w, None, 1, 1, 1));
    push_row(&mut t, &conv_label, &s1, &sn, &y1, &yn);

    let c = if smoke() { 48 } else { 128 };
    let rows = if smoke() { 128 } else { 512 };
    let wm = Tensor::new(vec![rows, c], rng.uniform_vec(rows * c, -1.0, 1.0));
    let xs = Tensor::new(vec![c, c + 8], rng.uniform_vec(c * (c + 8), -1.0, 1.0));
    let mut h = ops::matmul(&xs, &xs.t2());
    for i in 0..c {
        h.data[i * c + i] += 0.5;
    }
    let sweep = rk::sweep_matrix(&h).unwrap();
    let mask: Vec<f32> = (0..c).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let s1 = bench("obs_update/1t", warmup, iters, || {
        par::with_threads(1, || {
            let _ = rk::obs_update_native(&wm, &sweep, &mask);
        });
    });
    let sn = bench(&format!("obs_update/{threads}t"), warmup, iters, || {
        par::with_threads(threads, || {
            let _ = rk::obs_update_native(&wm, &sweep, &mask);
        });
    });
    let y1 = par::with_threads(1, || rk::obs_update_native(&wm, &sweep, &mask));
    let yn = par::with_threads(threads, || rk::obs_update_native(&wm, &sweep, &mask));
    push_row(&mut t, &format!("obs_update r{rows} c{c}"), &s1, &sn, &y1, &yn);

    let g = zoo::by_name(
        if smoke() { "resnet18" } else { "resnet50" },
        zoo::ImageCfg {
            hw: 8,
            ..Default::default()
        },
        3,
    )
    .unwrap();
    // grouping comes from a zero-sparsity session plan; the timed section
    // is the parallel Eq. 1 scoring alone, so the speedup ratio stays a
    // clean signal for the worker pool
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::Sparsity(0.0))
        .plan()
        .unwrap();
    let groups = plan.groups();
    let l1 = Criterion::L1.score(&g, None).unwrap();
    let s1 = bench("score/1t", warmup, iters, || {
        par::with_threads(1, || {
            let _ = score_groups(&g, groups, &l1, Agg::Sum, Norm::Mean);
        });
    });
    let sn = bench(&format!("score/{threads}t"), warmup, iters, || {
        par::with_threads(threads, || {
            let _ = score_groups(&g, groups, &l1, Agg::Sum, Norm::Mean);
        });
    });
    let r1 = par::with_threads(1, || score_groups(&g, groups, &l1, Agg::Sum, Norm::Mean));
    let rn = par::with_threads(threads, || score_groups(&g, groups, &l1, Agg::Sum, Norm::Mean));
    let mut bits = r1.len() == rn.len();
    for (p, q) in r1.iter().zip(&rn) {
        if (p.group, p.cc) != (q.group, q.cc) || p.score.to_bits() != q.score.to_bits() {
            bits = false;
        }
    }
    t.row(&[
        "importance scoring".to_string(),
        format!("{:.3}", s1.mean_ms()),
        format!("{:.3}", sn.mean_ms()),
        format!("{:.2}x", s1.mean_ns / sn.mean_ns.max(1.0)),
        verdict(bits),
    ]);

    t.print();
}

fn verdict(bits_equal: bool) -> String {
    if bits_equal {
        "identical".to_string()
    } else {
        "MISMATCH".to_string()
    }
}

fn push_row(
    t: &mut spa::util::Table,
    name: &str,
    s1: &spa::util::BenchStats,
    sn: &spa::util::BenchStats,
    y1: &Tensor,
    yn: &Tensor,
) {
    let mut bits = y1.shape == yn.shape;
    for (a, b) in y1.data.iter().zip(&yn.data) {
        if a.to_bits() != b.to_bits() {
            bits = false;
        }
    }
    t.row(&[
        name.to_string(),
        format!("{:.3}", s1.mean_ms()),
        format!("{:.3}", sn.mean_ms()),
        format!("{:.2}x", s1.mean_ns / sn.mean_ns.max(1.0)),
        verdict(bits),
    ]);
}
