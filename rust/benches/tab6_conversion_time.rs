//! Paper Tab. 6 — framework → standardized-graph conversion time
//! (PyTorch/TF/MXNet/JAX → ONNX in the paper; dialects → SPA-IR here),
//! averaged over 10 conversions, ResNet-18 and ResNet-50.

#[path = "common.rs"]
mod common;

use spa::frontends::{export_to_string, import_from_string, Dialect};
use spa::util::{bench, Table};
use spa::zoo;

fn main() {
    let mut t = Table::new(
        "Tab. 6 — dialect → SPA-IR conversion time (10 reps)",
        &["model", "dialect", "export+import (ms)", "paper (s, → ONNX)"],
    );
    let paper = [
        ("resnet18", ["0.51", "2.47", "2.28", "5.47"]),
        ("resnet50", ["2.01", "7.35", "7.36", "12.52"]),
    ];
    for (mi, model) in ["resnet18", "resnet50"].iter().enumerate() {
        let g = zoo::by_name(model, common::cifar_cfg(10), 3).unwrap();
        for (di, d) in Dialect::ALL.into_iter().enumerate() {
            let stats = bench(
                &format!("{model}/{}", d.name()),
                common::warmup(1),
                common::iters(10),
                || {
                    let s = export_to_string(&g, d);
                    let _ = import_from_string(&s).unwrap();
                },
            );
            t.row(&[
                model.to_string(),
                d.name().to_string(),
                format!("{:.1}", stats.mean_ms()),
                format!("{}s", paper[mi].1[di]),
            ]);
        }
    }
    t.print();
    println!("shape to check: conversion is seconds-scale or below for every framework");
}
