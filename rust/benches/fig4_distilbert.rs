//! Paper Fig. 4 — DistilBERT on SST-2 trade-off: OBSPA vs L1 one-shot
//! pruning without fine-tuning across compression ratios.

#[path = "common.rs"]
mod common;

use spa::analysis;
use spa::criteria::Criterion;
use spa::data::TextDataset;
use spa::obspa::{self, ObspaCfg};
use spa::train::{self, TrainCfg};
use spa::util::Table;
use spa::zoo::{self, TextCfg};
use spa::{Session, Target};

fn main() {
    let tcfg = TextCfg::default();
    let ds = TextDataset::synth_sst(2, 1024, tcfg.seq, tcfg.vocab, 31);
    let ood = TextDataset::synth_sst(4, 256, tcfg.seq, tcfg.vocab, 77); // ax stand-in
    let mut base = zoo::distilbert(tcfg, 5);
    train::train(
        &mut base,
        &ds,
        &TrainCfg { steps: common::steps(250), lr: 0.05, log_every: 0, ..Default::default() },
    )
    .unwrap();
    let base_acc = train::evaluate_text(&base, &ds, 256).unwrap();
    let mut t = Table::new(
        "Fig. 4 — distilbert-mini / SynthSST-2, prune without fine-tuning",
        &["method", "target RF", "RF", "RP", "acc.", "base acc."],
    );
    for rf in common::take_smoke(vec![1.2f64, 1.4, 1.7, 2.0]) {
        // L1 one-shot
        let pruned = Session::on(&base)
            .criterion(Criterion::L1)
            .min_keep(2)
            .target(Target::FlopsRf(rf))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        t.row(&[
            "L1 one-shot".into(),
            format!("{rf:.1}"),
            common::ratio(pruned.report.rf),
            common::ratio(pruned.report.rp),
            common::pct(train::evaluate_text(&pruned.graph, &ds, 256).unwrap()),
            common::pct(base_acc),
        ]);
        // OBSPA with OOD text calibration
        let mut g = base.clone();
        let (calib, _) = ood.train_batch_seeded(9, 64);
        obspa::obspa_prune(
            &mut g,
            &calib,
            &ObspaCfg { target_rf: rf, min_keep: 2, bn_recalibrate: false, ..Default::default() },
        )
        .unwrap();
        let r = analysis::reduction(&base, &g);
        t.row(&[
            "OBSPA (OOD)".into(),
            format!("{rf:.1}"),
            common::ratio(r.rf),
            common::ratio(r.rp),
            common::pct(train::evaluate_text(&g, &ds, 256).unwrap()),
            common::pct(base_acc),
        ]);
    }
    t.print();
    println!("shape to check (paper Fig. 4): OBSPA curve dominates L1 one-shot");
}
