//! Paper Tab. 13 — pruning wallclock: OBSPA vs DFPC. The paper's claim is
//! the *ratio* (OBSPA ≈ 6× faster than DFPC on ResNet-50); absolute times
//! differ by substrate. Our DFPC baseline re-runs its full per-channel
//! coupling analysis channel-by-channel the way DFPC's one-shot analysis
//! does, while OBSPA does one propagation per group + kernel updates.

#[path = "common.rs"]
mod common;

use spa::obspa::{self, ObspaCfg};
use spa::util::{time_once, Table};
use spa::zoo;

fn main() {
    let ds = common::synth_cifar10(97);
    let mut t = Table::new(
        "Tab. 13 — pruning time, OBSPA vs DFPC baseline",
        &["method", "model", "seconds", "paper"],
    );
    let models: [(&str, fn(spa::zoo::ImageCfg, u64) -> spa::ir::Graph); 3] = [
        ("resnet50", zoo::resnet50),
        ("resnet101", zoo::resnet101),
        ("vgg19", zoo::vgg19),
    ];
    let paper_dfpc = ["12 min", "-", "-"];
    let paper_obspa = ["1.5-2 min", "3-6 min", "3.5-4.5 min"];
    let mut ratio_r50 = (0.0f64, 0.0f64);
    for (i, (name, builder)) in common::take_smoke(models.to_vec()).into_iter().enumerate() {
        let base = common::train_base(builder(common::cifar_cfg(10), 3), &ds, 60);
        // DFPC
        let mut g = base.clone();
        let (_, dfpc_secs) = time_once(|| {
            spa::baselines::dfpc_prune(&mut g, 1.5, 1).unwrap();
        });
        t.row(&[
            "DFPC".into(),
            name.to_string(),
            format!("{dfpc_secs:.2}"),
            paper_dfpc[i].to_string(),
        ]);
        // OBSPA (includes graph analysis + hessians + reconstruction)
        let mut g = base.clone();
        let (calib, _) = ds.train_batch_seeded(7, 128);
        let (_, obspa_secs) = time_once(|| {
            obspa::obspa_prune(
                &mut g,
                &calib,
                &ObspaCfg { target_rf: 1.5, ..Default::default() },
            )
            .unwrap();
        });
        t.row(&[
            "OBSPA".into(),
            name.to_string(),
            format!("{obspa_secs:.2}"),
            paper_obspa[i].to_string(),
        ]);
        if i == 0 {
            ratio_r50 = (dfpc_secs, obspa_secs);
        }
    }
    t.print();
    println!(
        "resnet50 DFPC/OBSPA time ratio: {:.2} (paper: ~6x; both methods here share the fast\n\
         grouping machinery, so the ratio reflects reconstruction overhead only — see\n\
         EXPERIMENTS.md for discussion)",
        ratio_r50.0 / ratio_r50.1.max(1e-9)
    );
}
