//! Paper Fig. 3 — accuracy vs FLOPs/params trade-off on VGG-16/CIFAR-100:
//! SPA-grouped criteria vs their classic structured counterparts
//! (L1 vs SPA-L1, SNAP vs SPA-SNIP, s-CroP vs SPA-CroP, s-GraSP vs
//! SPA-GraSP), plus the one-shot vs iterative comparison.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::prune::Scope;
use spa::util::Table;
use spa::zoo;

fn main() {
    let ds = common::synth_cifar100(51);
    let ratios = common::take_smoke(vec![1.6f64, 2.4]);
    let mut t = Table::new(
        "Fig. 3 — vgg16-mini / SynthCIFAR-100 trade-off curves",
        &["criterion", "variant", "target RF", "RF", "RP", "final acc."],
    );
    let criteria = [
        (Criterion::L1, "L1"),
        (Criterion::Snip, "SNIP"),
        (Criterion::Crop, "CroP"),
        (Criterion::Grasp, "GraSP"),
    ];
    for (crit, name) in common::take_smoke(criteria.to_vec()) {
        for (scope, variant) in [
            (Scope::SourceOnly, "structured"),
            (Scope::FullCc, "SPA-grouped"),
        ] {
            for &rf in &ratios {
                let g = zoo::vgg16(common::cifar_cfg(20), 3);
                let rep = common::tpf(g, &ds, crit, scope, rf, 1);
                t.row(&[
                    name.to_string(),
                    variant.to_string(),
                    format!("{rf:.1}"),
                    common::ratio(rep.rf),
                    common::ratio(rep.rp),
                    common::pct(rep.final_acc),
                ]);
            }
        }
    }
    // iterative vs one-shot (L1, SPA-grouped)
    for (iters, label) in common::take_smoke(vec![(1usize, "one-shot"), (4, "iterative(4)")]) {
        let g = zoo::vgg16(common::cifar_cfg(20), 3);
        let rep = common::tpf(g, &ds, Criterion::L1, Scope::FullCc, 2.0, iters);
        t.row(&[
            "L1".into(),
            label.to_string(),
            "2.0".into(),
            common::ratio(rep.rf),
            common::ratio(rep.rp),
            common::pct(rep.final_acc),
        ]);
    }
    t.print();
    println!("shape to check (paper Fig. 3): SPA-grouped ≥ structured at equal RF;");
    println!("accuracy decays with RF; iterative ≥ one-shot.");
}
