//! Microbench: engine forward/backward throughput (the fine-tuning hot
//! path) across model families and batch sizes.

#[path = "common.rs"]
mod common;

use spa::engine::{self, Mode};
use spa::tensor::{ops, Tensor};
use spa::util::{bench, Rng, Table};
use spa::zoo;

fn main() {
    let mut t = Table::new(
        "micro — engine forward/backward (batch 32, 8x8)",
        &["model", "fwd (ms)", "fwd+bwd (ms)", "params"],
    );
    let mut rng = Rng::new(1);
    let models = common::take_smoke(vec!["mlp", "resnet18", "resnet50", "mobilenetv2", "vit"]);
    for name in models {
        let g = zoo::by_name(name, common::cifar_cfg(10), 3).unwrap();
        let x = Tensor::new(vec![32, 3, 8, 8], rng.uniform_vec(32 * 3 * 64, -1.0, 1.0));
        let labels: Vec<usize> = (0..32).map(|_| rng.below(10)).collect();
        let f = bench(&format!("{name}/fwd"), common::warmup(2), common::iters(8), || {
            let _ = engine::forward(&g, &[(g.inputs[0], x.clone())], Mode::Eval).unwrap();
        });
        let fb = bench(&format!("{name}/fwd+bwd"), common::warmup(2), common::iters(8), || {
            let fwd = engine::forward(&g, &[(g.inputs[0], x.clone())], Mode::Train).unwrap();
            let (_, dl) = ops::cross_entropy(fwd.logits(&g), &labels);
            let _ = engine::backward(&g, &fwd, &[(g.outputs[0], dl)]).unwrap();
        });
        t.row(&[
            name.to_string(),
            format!("{:.2}", f.mean_ms()),
            format!("{:.2}", fb.mean_ms()),
            format!("{}", g.num_params()),
        ]);
    }
    t.print();
}
