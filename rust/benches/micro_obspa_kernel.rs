//! Microbench: the OBSPA hot kernel — PJRT (Pallas artifact) vs the
//! native Rust fallback across the canonical column ladder, plus the
//! Hessian accumulation kernel. Run after `make artifacts` to get the
//! PJRT rows.

#[path = "common.rs"]
mod common;

use spa::runtime::{kernels as rk, Runtime};
use spa::tensor::{ops, Tensor};
use spa::util::{bench, Rng, Table};

fn main() {
    let smoke = common::smoke();
    let (warm, iters) = (common::warmup(1), common::iters(5));
    let has_pjrt = Runtime::global().is_some();
    println!("PJRT artifacts: {}", if has_pjrt { "loaded" } else { "NOT FOUND (native only)" });
    let mut rng = Rng::new(1);
    let mut t = Table::new(
        "micro — obs_update / hessian kernels (rows = 128)",
        &["kernel", "C", "native (ms)", "pjrt (ms)"],
    );
    let obs_cols: &[usize] = if smoke { &[32] } else { &[32, 64, 128, 256] };
    for &c in obs_cols {
        let w = Tensor::new(vec![128, c], rng.uniform_vec(128 * c, -1.0, 1.0));
        let xs = Tensor::new(vec![c, c + 8], rng.uniform_vec(c * (c + 8), -1.0, 1.0));
        let mut h = ops::matmul(&xs, &xs.t2());
        for i in 0..c {
            h.data[i * c + i] += 0.5;
        }
        let sweep = rk::sweep_matrix(&h).unwrap();
        let mask: Vec<f32> = (0..c).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let n = bench(&format!("obs_update_native/c{c}"), warm, iters, || {
            let _ = rk::obs_update_native(&w, &sweep, &mask);
        });
        let p = if has_pjrt {
            let s = bench(&format!("obs_update_pjrt/c{c}"), warm, iters, || {
                let _ = rk::obs_update(&w, &sweep, &mask).unwrap();
            });
            format!("{:.3}", s.mean_ms())
        } else {
            "-".into()
        };
        t.row(&["obs_update".into(), format!("{c}"), format!("{:.3}", n.mean_ms()), p]);
    }
    let hess_cols: &[usize] = if smoke { &[64] } else { &[64, 128, 256] };
    for &c in hess_cols {
        let h = Tensor::zeros(&[c, c]);
        let x = Tensor::new(vec![c, 128], rng.uniform_vec(c * 128, -1.0, 1.0));
        let n = bench(&format!("hessian_native/c{c}"), warm, iters, || {
            let _ = rk::hessian_accum_native(&h, &x);
        });
        let p = if has_pjrt {
            let s = bench(&format!("hessian_pjrt/c{c}"), warm, iters, || {
                let _ = rk::hessian_accum(&h, &x).unwrap();
            });
            format!("{:.3}", s.mean_ms())
        } else {
            "-".into()
        };
        t.row(&["hessian".into(), format!("{c}"), format!("{:.3}", n.mean_ms()), p]);
    }
    t.print();
}
