//! Microbench: mask propagation + grouping throughput (the O(|E|)
//! analysis of paper §3.2), and structural pruning application.

#[path = "common.rs"]
mod common;

use spa::prune::{self, build_groups, score_groups, Agg, Norm};
use spa::util::{bench, Table};
use spa::zoo;
use std::collections::HashMap;

fn main() {
    let mut t = Table::new(
        "micro — grouping & pruning throughput",
        &["model", "ops", "group (ms)", "score (ms)", "prune-apply (ms)"],
    );
    let models = common::take_smoke(vec!["resnet18", "resnet50", "resnet101", "densenet", "vit"]);
    for name in models {
        let g = zoo::by_name(name, common::cifar_cfg(10), 3).unwrap();
        let gstats = bench(&format!("{name}/group"), common::warmup(1), common::iters(5), || {
            let _ = build_groups(&g).unwrap();
        });
        let groups = build_groups(&g).unwrap();
        let mut l1 = HashMap::new();
        for pid in g.param_ids() {
            l1.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
        }
        let sstats = bench(&format!("{name}/score"), common::warmup(1), common::iters(5), || {
            let _ = score_groups(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        });
        let ranked = score_groups(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        let sel = prune::select_lowest(&groups, &ranked, 0.4, 1);
        let pstats = bench(&format!("{name}/apply"), common::warmup(1), common::iters(5), || {
            let mut gc = g.clone();
            prune::apply_pruning(&mut gc, &groups, &sel).unwrap();
        });
        t.row(&[
            name.to_string(),
            format!("{}", g.ops.len()),
            format!("{:.2}", gstats.mean_ms()),
            format!("{:.2}", sstats.mean_ms()),
            format!("{:.2}", pstats.mean_ms()),
        ]);
    }
    t.print();
}
