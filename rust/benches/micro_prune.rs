//! Microbench: session planning throughput (mask propagation + grouping
//! — the O(|E|) analysis of paper §3.2 — plus Eq. 1 scoring, selection,
//! and the one physical pruning pass) and `Plan::apply` materialization.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::util::{bench, Table};
use spa::zoo;
use spa::{Session, Target};

fn main() {
    let mut t = Table::new(
        "micro — session plan & prune-apply throughput",
        &["model", "ops", "plan+prune (ms)", "apply (ms)"],
    );
    let models = common::take_smoke(vec!["resnet18", "resnet50", "resnet101", "densenet", "vit"]);
    for name in models {
        let g = zoo::by_name(name, common::cifar_cfg(10), 3).unwrap();
        let session = || {
            Session::on(&g)
                .criterion(Criterion::L1)
                .target(Target::Sparsity(0.4))
        };
        let pstats = bench(&format!("{name}/plan"), common::warmup(1), common::iters(5), || {
            let _ = session().plan().unwrap();
        });
        let plan = session().plan().unwrap();
        let astats = bench(&format!("{name}/apply"), common::warmup(1), common::iters(5), || {
            let _ = plan.apply().unwrap();
        });
        t.row(&[
            name.to_string(),
            format!("{}", g.ops.len()),
            format!("{:.2}", pstats.mean_ms()),
            format!("{:.2}", astats.mean_ms()),
        ]);
    }
    t.print();
}
