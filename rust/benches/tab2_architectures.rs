//! Paper Tab. 2 — "Prune Any Architecture": 11 architectures pruned ~2×
//! with SPA-L1 + fine-tuning (CIFAR-10 / SST-2 → SynthCIFAR-10/SynthSST).

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::data::TextDataset;
use spa::prune::Scope;
use spa::train::{self, TrainCfg};
use spa::util::Table;
use spa::zoo::{self, TextCfg};
use spa::{Session, Target};
use std::collections::HashMap;

fn main() {
    let ds = common::synth_cifar10(42);
    let paper: HashMap<&str, &str> = [
        ("alexnet", "89.99→89.80 / 1.98x"),
        ("densenet", "93.30→94.20 / 2.14x"),
        ("efficientnet", "94.15→92.06 / 2.14x"),
        ("mobilenetv2", "92.33→92.54 / 2.33x"),
        ("regnet", "93.83→93.75 / 2.13x"),
        ("resnet50", "93.26→93.42 / 2.13x"),
        ("resnext", "93.95→93.99 / 2.07x"),
        ("vgg16", "93.82→94.06 / 2.05x"),
        ("wideresnet", "93.50→93.41 / 2.00x"),
        ("vit", "95.35→96.10 / 2.05x"),
        ("distilbert", "91.06→88.88 / 2.04x"),
    ]
    .into_iter()
    .collect();
    let mut t = Table::new(
        "Tab. 2 — SPA-L1 ~2x across architectures (SynthCIFAR-10 / SynthSST-2)",
        &["model", "ori acc.", "pruned acc.", "RF", "RP", "paper (acc / RF)"],
    );
    for name in common::take_smoke(zoo::IMAGE_MODELS.to_vec()) {
        let g = zoo::by_name(name, common::cifar_cfg(10), 7).expect("model");
        let rep = common::tpf(g, &ds, Criterion::L1, Scope::FullCc, 2.0, 1);
        t.row(&[
            name.to_string(),
            common::pct(rep.ori_acc),
            common::pct(rep.final_acc),
            common::ratio(rep.rf),
            common::ratio(rep.rp),
            paper[name].to_string(),
        ]);
    }
    // DistilBERT on text
    {
        let tcfg = TextCfg::default();
        let tds = TextDataset::synth_sst(2, 1024, tcfg.seq, tcfg.vocab, 5);
        let mut g = zoo::distilbert(tcfg, 5);
        let tr = TrainCfg {
            steps: common::steps(150),
            lr: 0.05,
            log_every: 0,
            ..Default::default()
        };
        train::train(&mut g, &tds, &tr).unwrap();
        let ori = train::evaluate_text(&g, &tds, 256).unwrap();
        let pruned = Session::on(&g)
            .criterion(Criterion::L1)
            .min_keep(2)
            .target(Target::FlopsRf(2.0))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        let mut g = pruned.graph;
        let mut ft = tr.clone();
        ft.steps = common::steps(80);
        ft.lr = 0.02;
        train::train(&mut g, &tds, &ft).unwrap();
        let fin = train::evaluate_text(&g, &tds, 256).unwrap();
        t.row(&[
            "distilbert".into(),
            common::pct(ori),
            common::pct(fin),
            common::ratio(pruned.report.rf),
            common::ratio(pruned.report.rp),
            paper["distilbert"].to_string(),
        ]);
    }
    t.print();
    println!("shape to check: all 11 architectures prune to ~2x RF with pruned acc ≈ ori acc");
}
