//! Paper Tab. 7 — DenseNet-121 on ImageNet with fine-tuning:
//! SPA-L1 and OBSPA(+finetune) vs the ungrouped DepGraph proxy.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::obspa::{self, ObspaCfg};
use spa::prune::Scope;
use spa::train::{self, TrainCfg};
use spa::util::Table;
use spa::zoo;
use spa::{Session, Target};

fn main() {
    let ds = common::synth_imagenet(71);
    let base = common::train_base(zoo::densenet(common::cifar_cfg(20), 8), &ds, 200);
    let base_acc = train::evaluate(&base, &ds, 384).unwrap();
    let ft = TrainCfg { steps: common::steps(80), lr: 0.02, log_every: 0, ..Default::default() };
    let mut t = Table::new(
        "Tab. 7 — densenet-mini / SynthImageNet with fine-tuning",
        &["method", "top1 acc.", "RF", "RP", "paper top1 / RF"],
    );
    t.row(&[
        "Base Model".into(),
        common::pct(base_acc),
        "1x".into(),
        "1x".into(),
        "74.43% / 1x".into(),
    ]);
    // DepGraph proxy: ungrouped structured L1
    {
        let pruned = Session::on(&base)
            .criterion(Criterion::L1)
            .scope(Scope::SourceOnly)
            .target(Target::FlopsRf(2.1))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        let mut g = pruned.graph;
        train::train(&mut g, &ds, &ft).unwrap();
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        t.row(&[
            "ungrouped-L1 (DepGraph proxy)".into(),
            common::pct(acc),
            common::ratio(pruned.report.rf),
            common::ratio(pruned.report.rp),
            "73.98% / 2.09x".into(),
        ]);
    }
    // SPA-L1
    {
        let pruned = Session::on(&base)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(2.1))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        let mut g = pruned.graph;
        train::train(&mut g, &ds, &ft).unwrap();
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        t.row(&[
            "SPA-L1".into(),
            common::pct(acc),
            common::ratio(pruned.report.rf),
            common::ratio(pruned.report.rp),
            "74.39% / 2.09x".into(),
        ]);
    }
    // OBSPA + finetune
    {
        let mut g = base.clone();
        let (calib, _) = ds.train_batch_seeded(11, 128);
        obspa::obspa_prune(&mut g, &calib, &ObspaCfg { target_rf: 1.8, ..Default::default() })
            .unwrap();
        train::train(&mut g, &ds, &ft).unwrap();
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        let r = spa::analysis::reduction(&base, &g);
        t.row(&[
            "OBSPA + finetune".into(),
            common::pct(acc),
            common::ratio(r.rf),
            common::ratio(r.rp),
            "74.62% / 1.78x".into(),
        ]);
    }
    t.print();
    println!("shape to check: SPA-L1 ≈ base ≥ ungrouped proxy at ~2.1x; OBSPA ≥ base at 1.8x");
}
