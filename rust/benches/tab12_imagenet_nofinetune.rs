//! Paper Tab. 12 — ResNet-50 on ImageNet without fine-tuning, OBSPA at
//! low/high compression + OOD + DataFree.

#[path = "common.rs"]
mod common;

use spa::train;
use spa::util::Table;
use spa::zoo;

fn main() {
    let ds = common::synth_imagenet(95);
    let ood = common::synth_cifar10(96); // ImageNet-O stand-in
    let base = common::train_base(zoo::resnet50(common::cifar_cfg(20), 29), &ds, 250);
    let base_acc = train::evaluate(&base, &ds, 384).unwrap();
    let mut t = Table::new(
        "Tab. 12 — resnet50-mini / SynthImageNet without fine-tuning",
        &["method", "accuracy", "RF", "RP", "paper acc / RF"],
    );
    t.row(&[
        "Base Model".into(),
        common::pct(base_acc),
        "1x".into(),
        "1x".into(),
        "76.15% / 1x".into(),
    ]);
    let runs = [
        ("OBSPA (ID) - Low", common::OBSPA_ID, 1.22, "74.27% / 1.22x"),
        ("OBSPA (ID) - High", common::OBSPA_ID, 1.43, "70.57% / 1.43x"),
        ("OBSPA (OOD) - Low", common::OBSPA_OOD, 1.25, "71.60% / 1.25x"),
        ("OBSPA (DataFree) - Low", common::OBSPA_DF, 1.21, "70.13% / 1.21x"),
    ];
    for (name, algo, rf, paper) in common::take_smoke(runs.to_vec()) {
        let rep = common::no_finetune(base.clone(), &ds, Some(&ood), algo, rf);
        t.row(&[
            name.to_string(),
            common::pct(rep.final_acc),
            common::ratio(rep.rf),
            common::ratio(rep.rp),
            paper.to_string(),
        ]);
    }
    t.print();
    println!("shape to check: acc decreases with compression; ID ≥ OOD ≥ DataFree");
}
