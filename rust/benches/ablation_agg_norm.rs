//! Ablation (paper §3.2: "the best choice of AGG and Norm … can be
//! regarded as hyper-parameters"): sweep the Eq. 1 aggregation and
//! normalization operators on two architectures at fixed RF and report
//! the no-finetune accuracy of each combination.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::prune::{Agg, Norm};
use spa::train;
use spa::util::Table;
use spa::zoo;
use spa::{Session, Target};

fn main() {
    let ds = common::synth_cifar10(99);
    let mut t = Table::new(
        "Ablation — Eq. 1 AGG × Norm (no-finetune acc at RF 1.5)",
        &["model", "AGG", "Norm", "acc.", "RF"],
    );
    for (mname, seed) in common::take_smoke(vec![("resnet18", 3u64), ("densenet", 4u64)]) {
        let base = common::train_base(
            zoo::by_name(mname, common::cifar_cfg(10), seed).unwrap(),
            &ds,
            180,
        );
        for agg in common::take_smoke(vec![Agg::Sum, Agg::Mean, Agg::Max, Agg::L2]) {
            for norm in common::take_smoke(vec![Norm::Sum, Norm::Mean, Norm::Max, Norm::None]) {
                let pruned = Session::on(&base)
                    .criterion(Criterion::L1)
                    .agg(agg)
                    .norm(norm)
                    .target(Target::FlopsRf(1.5))
                    .plan()
                    .unwrap()
                    .apply()
                    .unwrap();
                let acc = train::evaluate(&pruned.graph, &ds, 256).unwrap();
                t.row(&[
                    mname.to_string(),
                    format!("{agg:?}"),
                    format!("{norm:?}"),
                    common::pct(acc),
                    common::ratio(pruned.report.rf),
                ]);
            }
        }
    }
    t.print();
    println!("shape to check: no single AGG/Norm dominates both models (they are");
    println!("per-model hyper-parameters, as the paper states)");
}
