//! Microbench: `spa::serve` load generator — p50/p99 latency and
//! throughput at 1/8/64 concurrent clients against an in-process server.
//!
//! The 1-client run is the sequential baseline: every request pays a
//! full batcher tick alone. Concurrent clients coalesce into shared
//! batches, so 8 clients must clear ≥ 2x the sequential request rate
//! (asserted — this is the ISSUE-6 acceptance case). Responses are
//! gated bit-identical against a local `Plan::predict` before timing.

#[path = "common.rs"]
mod common;

use spa::exec::{Plan, PlanOpts};
use spa::serve::{Client, ServeCfg, Server};
use spa::tensor::Tensor;
use spa::util::{bench, Rng, Table};
use spa::zoo;
use std::net::SocketAddr;
use std::sync::Mutex;
use std::time::{Duration, Instant};

const MODEL: &str = "mlp";

struct LoadResult {
    p50_us: u64,
    p99_us: u64,
    req_per_sec: f64,
}

/// Drive `clients` connections of `per_client` sequential requests each;
/// percentiles are client-observed round-trip times.
fn run_load(addr: SocketAddr, clients: usize, per_client: usize, x: &Tensor) -> LoadResult {
    let lats: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            s.spawn(|| {
                let mut c = Client::connect(addr).expect("connect");
                let mut local = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let q0 = Instant::now();
                    let (_y, _server_us) = c.predict(MODEL, x).expect("predict");
                    local.push(q0.elapsed().as_micros() as u64);
                }
                lats.lock().unwrap().extend(local);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let mut v = lats.into_inner().unwrap();
    v.sort_unstable();
    let pick = |p: f64| v[((p / 100.0) * (v.len() - 1) as f64).round() as usize];
    LoadResult {
        p50_us: pick(50.0),
        p99_us: pick(99.0),
        req_per_sec: (clients * per_client) as f64 / wall,
    }
}

fn main() {
    let image = common::cifar_cfg(10);
    let seed = 1;
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(2),
        max_batch: 64,
        cache_cap: 2,
        image,
        seed,
        // the 64-client phase keeps ~64 requests in flight; keep the
        // admission cap far above that so the bench never sheds with
        // `Overloaded` and the latency numbers stay pure batching
        queue_cap: 4096,
        ..Default::default()
    })
    .expect("server spawn");
    let addr = server.local_addr();

    let mut rng = Rng::new(7);
    let numel = image.channels * image.hw * image.hw;
    let x = Tensor::new(
        vec![1, image.channels, image.hw, image.hw],
        rng.uniform_vec(numel, -1.0, 1.0),
    );

    // parity gate before timing: the served bits must equal a local plan
    let g = zoo::by_name(MODEL, image, seed).unwrap();
    let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
    let want = plan.predict(&x).unwrap();
    let mut probe = Client::connect(addr).expect("probe connect");
    let (got, _us) = probe.predict(MODEL, &x).expect("probe predict");
    assert_eq!(want.shape, got.shape, "served shape drift");
    for (a, b) in want.data.iter().zip(&got.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "served bits must match Plan::predict");
    }
    drop(probe);

    let per_client = if common::smoke() { 16 } else { 128 };
    let mut t = Table::new(
        "micro — serve: dynamic batching under concurrent clients (mlp, 2ms tick)",
        &["clients", "requests", "p50 (us)", "p99 (us)", "req/s"],
    );
    let mut rates: Vec<(usize, f64)> = Vec::new();
    for &clients in &[1usize, 8, 64] {
        let mut last = None;
        bench(&format!("serve/clients{clients}"), 0, 1, || {
            last = Some(run_load(addr, clients, per_client, &x));
        });
        let r = last.expect("one load run");
        t.row(&[
            clients.to_string(),
            (clients * per_client).to_string(),
            r.p50_us.to_string(),
            r.p99_us.to_string(),
            format!("{:.0}", r.req_per_sec),
        ]);
        rates.push((clients, r.req_per_sec));
    }
    t.print();

    let rps = |n: usize| rates.iter().find(|(c, _)| *c == n).unwrap().1;
    assert!(
        rps(8) >= 2.0 * rps(1),
        "batching must beat sequential 2x: 8 clients {:.0} req/s vs 1 client {:.0} req/s",
        rps(8),
        rps(1)
    );
    println!(
        "batching speedup at 8 clients: {:.2}x over sequential",
        rps(8) / rps(1)
    );

    // obs lane: the same 8-client load with trace recording on. The
    // bench-diff gate holds this entry to the same <25% warn threshold
    // as every other bench, and the served bits must stay identical.
    spa::obs::ObsCfg::tracing().apply();
    let mut probe = Client::connect(addr).expect("obs probe connect");
    let (got, _us) = probe.predict(MODEL, &x).expect("obs probe predict");
    assert_eq!(want.shape, got.shape, "traced shape drift");
    for (a, b) in want.data.iter().zip(&got.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "traced serving must stay bit-identical");
    }
    drop(probe);
    let mut last = None;
    bench("serve/clients8_obs", 0, 1, || {
        last = Some(run_load(addr, 8, per_client, &x));
    });
    spa::obs::ObsCfg::default().apply();
    let buf = spa::obs::trace::drain();
    let r = last.expect("one obs load run");
    assert!(!buf.events.is_empty(), "traced serving must record events");
    println!(
        "obs lane: 8 clients {:.0} req/s traced vs {:.0} untraced, {} event(s) recorded",
        r.req_per_sec,
        rps(8),
        buf.events.len() as u64 + buf.dropped
    );
    server.shutdown();
}
