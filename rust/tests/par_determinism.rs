//! Property-based determinism tests for the `util::par` worker pool: at
//! any `SPA_THREADS`, parallel execution must produce results that are
//! bit-identical to single-threaded execution — for the GEMM/conv hot
//! path, the OBSPA native kernels, and per-group importance scoring.

use spa::ir::Graph;
use spa::prune::{build_groups, score_groups, Agg, Norm};
use spa::runtime::kernels as rk;
use spa::tensor::{ops, Tensor};
use spa::util::par;
use spa::util::proptest::check;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use std::collections::HashMap;

/// Bit-exact tensor equality (no tolerance: determinism, not accuracy).
fn assert_bits_equal(a: &Tensor, b: &Tensor, what: &str) -> Result<(), String> {
    if a.shape != b.shape {
        return Err(format!("{what}: shape {:?} vs {:?}", a.shape, b.shape));
    }
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{what}: bit mismatch at {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn prop_matmul_parallel_matches_single_thread() {
    let _serial = par::test_lock();
    check(
        "matmul-thread-determinism",
        12,
        0x9A55,
        |rng| {
            // shapes straddling the parallel threshold, including large
            let m = 1 + rng.below(300);
            let k = 1 + rng.below(64);
            let n = 1 + rng.below(300);
            let a = Tensor::new(vec![m, k], rng.uniform_vec(m * k, -1.0, 1.0));
            let b = Tensor::new(vec![k, n], rng.uniform_vec(k * n, -1.0, 1.0));
            (a, b)
        },
        |(a, b)| {
            let serial = par::with_threads(1, || ops::matmul(a, b));
            for threads in [2usize, 4, 8] {
                let parallel = par::with_threads(threads, || ops::matmul(a, b));
                assert_bits_equal(&parallel, &serial, &format!("matmul t={threads}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_conv2d_parallel_matches_single_thread() {
    let _serial = par::test_lock();
    check(
        "conv2d-thread-determinism",
        8,
        0xC0117,
        |rng| {
            let n = 1 + rng.below(6);
            let groups = [1usize, 2][rng.below(2)];
            let ci = groups * (1 + rng.below(4));
            let co = groups * (1 + rng.below(6));
            let hw = 4 + rng.below(10);
            let k = [1usize, 3][rng.below(2)];
            let x = Tensor::new(
                vec![n, ci, hw, hw],
                rng.uniform_vec(n * ci * hw * hw, -1.0, 1.0),
            );
            let w = Tensor::new(
                vec![co, ci / groups, k, k],
                rng.uniform_vec(co * (ci / groups) * k * k, -0.5, 0.5),
            );
            (x, w, k / 2, groups)
        },
        |(x, w, pad, groups)| {
            let serial = par::with_threads(1, || ops::conv2d(x, w, None, 1, *pad, *groups));
            for threads in [2usize, 4] {
                let parallel =
                    par::with_threads(threads, || ops::conv2d(x, w, None, 1, *pad, *groups));
                assert_bits_equal(&parallel, &serial, &format!("conv2d t={threads}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_obspa_kernels_parallel_match_single_thread() {
    let _serial = par::test_lock();
    check(
        "obspa-kernel-thread-determinism",
        8,
        0x0B5,
        |rng| {
            let c = 8 + rng.below(56);
            let r = 1 + rng.below(300);
            let m = 16 + rng.below(128);
            let w = Tensor::new(vec![r, c], rng.uniform_vec(r * c, -1.0, 1.0));
            let x = Tensor::new(vec![c, m], rng.uniform_vec(c * m, -1.0, 1.0));
            let h0 = Tensor::zeros(&[c, c]);
            let mask: Vec<f32> = (0..c)
                .map(|_| if rng.below(3) == 0 { 1.0 } else { 0.0 })
                .collect();
            (w, x, h0, mask)
        },
        |(w, x, h0, mask)| {
            let c = h0.shape[0];
            let sweep = par::with_threads(1, || {
                let mut h = rk::hessian_accum_native(h0, x);
                let damp = 0.01 * (0..c).map(|i| h.data[i * c + i]).sum::<f32>() / c as f32;
                for i in 0..c {
                    h.data[i * c + i] += damp.max(1e-6);
                }
                rk::sweep_matrix(&h).unwrap()
            });
            let h_serial = par::with_threads(1, || rk::hessian_accum_native(h0, x));
            let obs_serial = par::with_threads(1, || rk::obs_update_native(w, &sweep, mask));
            for threads in [2usize, 4] {
                let h_par = par::with_threads(threads, || rk::hessian_accum_native(h0, x));
                assert_bits_equal(&h_par, &h_serial, &format!("hessian t={threads}"))?;
                let obs_par =
                    par::with_threads(threads, || rk::obs_update_native(w, &sweep, mask));
                assert_bits_equal(&obs_par, &obs_serial, &format!("obs_update t={threads}"))?;
            }
            Ok(())
        },
    );
}

fn l1_scores(g: &Graph) -> HashMap<usize, Tensor> {
    g.param_ids()
        .into_iter()
        .map(|id| (id, g.data(id).param().unwrap().map(f32::abs)))
        .collect()
}

#[test]
fn prop_importance_scoring_parallel_matches_single_thread() {
    let _serial = par::test_lock();
    check(
        "importance-thread-determinism",
        6,
        0x15C0,
        |rng| {
            let names = ["resnet18", "densenet", "mobilenetv2", "vgg16"];
            let name = names[rng.below(names.len())];
            let cfg = ImageCfg {
                hw: 8,
                ..Default::default()
            };
            zoo::by_name(name, cfg, rng.next_u64()).unwrap()
        },
        |g| {
            let groups = build_groups(g).map_err(|e| e.to_string())?;
            let scores = l1_scores(g);
            let serial =
                par::with_threads(1, || score_groups(g, &groups, &scores, Agg::Sum, Norm::Mean));
            for threads in [2usize, 4] {
                let parallel = par::with_threads(threads, || {
                    score_groups(g, &groups, &scores, Agg::Sum, Norm::Mean)
                });
                if parallel.len() != serial.len() {
                    return Err(format!(
                        "score count {} vs {} at t={threads}",
                        parallel.len(),
                        serial.len()
                    ));
                }
                for (a, b) in parallel.iter().zip(&serial) {
                    if (a.group, a.cc) != (b.group, b.cc) || a.score.to_bits() != b.score.to_bits()
                    {
                        return Err(format!(
                            "score mismatch at t={threads}: ({},{}) {} vs ({},{}) {}",
                            a.group, a.cc, a.score, b.group, b.cc, b.score
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn parallel_speedup_is_observable_on_large_gemm() {
    let _serial = par::test_lock();
    // Not a strict perf gate (CI machines vary) — but with 4 workers a
    // 384^3 GEMM must not be slower than single-threaded by more than a
    // generous margin, and the results must match bitwise. The margin is
    // wide (2.5x) so noisy shared runners cannot flake an otherwise
    // correct build; `cargo bench --bench micro_par` reports real ratios.
    let mut rng = Rng::new(1);
    let n = 384;
    let a = Tensor::new(vec![n, n], rng.uniform_vec(n * n, -1.0, 1.0));
    let b = Tensor::new(vec![n, n], rng.uniform_vec(n * n, -1.0, 1.0));
    let t0 = std::time::Instant::now();
    let serial = par::with_threads(1, || ops::matmul(&a, &b));
    let serial_time = t0.elapsed();
    let t1 = std::time::Instant::now();
    let parallel = par::with_threads(4, || ops::matmul(&a, &b));
    let parallel_time = t1.elapsed();
    assert_bits_equal(&parallel, &serial, "speedup gemm").unwrap();
    assert!(
        parallel_time.as_secs_f64() < serial_time.as_secs_f64() * 2.5,
        "parallel {parallel_time:?} much slower than serial {serial_time:?}"
    );
}
