//! Chaos suite: the serving stack under deterministic fault injection.
//!
//! Every test spawns a server with a seeded [`FaultPlan`] and drives it
//! with real clients over loopback. The invariants are absolute, at any
//! seed and any `SPA_THREADS`:
//!
//!   - no client ever hangs — every request gets an answer;
//!   - every answer is either a typed `ServeError` or a response
//!     bit-identical to a local `Plan::predict` on the same build;
//!   - the server keeps serving after every injected fault.
//!
//! CI runs this file across a seed matrix; set `SPA_CHAOS_SEED` to
//! replay a particular lane locally, e.g.
//! `SPA_CHAOS_SEED=2 cargo test --test serve_chaos`.

use spa::criteria::Criterion;
use spa::exec::{Plan, PlanOpts};
use spa::ir::Graph;
use spa::serve::{
    faults, Client, ErrorCode, FaultPlan, RetryCfg, ServeCfg, ServeError, Server, Site,
    SwapOutcome, SwapRequest, SwapStage,
};
use spa::tensor::Tensor;
use spa::zoo::{self, ImageCfg};
use spa::{CheckLevel, Session, Target};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Once};
use std::time::Duration;

const MODEL: &str = "mlp";
const SEED: u64 = 3; // zoo weight seed — must match ServeCfg.seed

fn image() -> ImageCfg {
    ImageCfg {
        channels: 3,
        hw: 8,
        classes: 10,
        batch: 8,
    }
}

/// The fault seed for this run: `SPA_CHAOS_SEED` (CI matrixes over it),
/// default 1.
fn chaos_seed() -> u64 {
    std::env::var("SPA_CHAOS_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1)
}

/// Injected panics are expected output here; silence their backtraces
/// so a green run isn't pages of red. Real (untagged) panics still
/// reach the default hook.
fn quiet_injected_panics() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let tagged = match payload.downcast_ref::<String>() {
                Some(s) => s.contains(faults::PANIC_TAG),
                None => match payload.downcast_ref::<&str>() {
                    Some(s) => s.contains(faults::PANIC_TAG),
                    None => false,
                },
            };
            if !tagged {
                default(info);
            }
        }));
    });
}

fn spawn(spec: &str, cfg: ServeCfg) -> Server {
    quiet_injected_panics();
    let faults = Arc::new(FaultPlan::parse(spec).expect("fault spec"));
    Server::spawn(ServeCfg {
        faults: Some(faults),
        ..cfg
    })
    .expect("server spawn")
}

/// One request over the wire; a transport-level failure aborts the
/// test, a typed server error comes back as `Err`.
fn ask(c: &mut Client, model: &str, x: &Tensor) -> Result<(Tensor, u32), ServeError> {
    c.try_predict(model, x, Duration::ZERO).expect("transport")
}

/// [`ask`] with a soft deadline.
fn ask_dl(c: &mut Client, x: &Tensor, d: Duration) -> Result<(Tensor, u32), ServeError> {
    c.try_predict(MODEL, x, d).expect("transport")
}

/// The reference every surviving response is gated against.
fn reference(x: &Tensor) -> Tensor {
    let g = zoo::by_name(MODEL, image(), SEED).unwrap();
    let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
    plan.predict(x).unwrap()
}

/// Re-prune `base` exactly the way the server's swap pipeline does: a
/// Strict l1 session at `target_rf`, applied to a clone as a verified
/// patch. At the serve default `OptLevel::Exact` the serving plan's
/// graph is the compile input verbatim, so chaining this replays the
/// server's generation lineage bit-for-bit.
fn repruned(base: &Graph, target_rf: f64) -> Graph {
    let sess = Session::on(base)
        .criterion(Criterion::L1)
        .target(Target::FlopsRf(target_rf))
        .check(CheckLevel::Strict)
        .plan()
        .unwrap();
    let patch = sess.as_patch(base).unwrap();
    let mut patched = base.clone();
    patch
        .apply_checked(&mut patched, CheckLevel::Strict)
        .unwrap();
    patched
}

fn plan_predict(g: &Graph, x: &Tensor) -> Tensor {
    let plan = Plan::compile(g, PlanOpts::default()).unwrap();
    plan.predict(x).unwrap()
}

fn bits_equal(y: &Tensor, want: &Tensor) -> bool {
    y.shape == want.shape
        && y.data
            .iter()
            .zip(&want.data)
            .all(|(a, b)| a.to_bits() == b.to_bits())
}

fn swap_req(target_rf: f64, shadow: u32) -> SwapRequest {
    SwapRequest {
        model: MODEL.to_string(),
        target_rf,
        criterion: "l1".to_string(),
        shadow,
        max_divergence: f64::INFINITY,
    }
}

fn assert_bit_identical(y: &Tensor, want: &Tensor, who: &str) {
    assert_eq!(y.shape, want.shape, "{who}: shape drift");
    for (a, b) in y.data.iter().zip(&want.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "{who}: must be bit-identical");
    }
}

/// Panics injected into batch groups surface as typed `Panic` errors on
/// exactly the affected requests; everything else is bit-identical, and
/// the batch loop survives to serve more.
#[test]
fn group_panics_become_typed_errors_and_the_loop_survives() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};group.panic=0.4", chaos_seed()), cfg);
    let addr = server.local_addr();
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.5; 3 * 64]);
    let want = reference(&x);

    const CLIENTS: usize = 4;
    const REQS: usize = 10;
    let mut oks = 0usize;
    let mut panics = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| {
                let (x, want) = (&x, &want);
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    let (mut oks, mut panics) = (0usize, 0usize);
                    for _ in 0..REQS {
                        match ask(&mut c, MODEL, x) {
                            Ok((y, _us)) => {
                                assert_bit_identical(&y, want, &format!("client {i}"));
                                oks += 1;
                            }
                            Err(e) => {
                                // only the injected panic may fail requests
                                assert_eq!(e.code, ErrorCode::Panic, "got: {e}");
                                assert!(e.message.contains(MODEL), "got: {e}");
                                panics += 1;
                            }
                        }
                    }
                    (oks, panics)
                })
            })
            .collect();
        for h in handles {
            let (o, p) = h.join().expect("client thread");
            oks += o;
            panics += p;
        }
    });

    let stats = server.stats();
    assert_eq!(oks + panics, CLIENTS * REQS, "every request was answered");
    assert_eq!(stats.served(), CLIENTS * REQS);
    assert_eq!(stats.errors(), panics);
    if panics > 0 {
        assert!(stats.panics() >= 1, "panic counter must record unwinds");
    }
    // recovery: at prob 0.4 a handful of retries must land an Ok — the
    // loop is still alive and still correct after every unwind
    let mut c = Client::connect(addr).expect("reconnect");
    let mut recovered = None;
    for _ in 0..50 {
        if let Ok((y, _us)) = ask(&mut c, MODEL, &x) {
            recovered = Some(y);
            break;
        }
    }
    let y = recovered.expect("server must keep serving after panics");
    assert_bit_identical(&y, &want, "recovery");
    server.shutdown();
}

/// An injected slow batch pushes queued work past its deadline: the
/// expired request gets a typed `DeadlineExceeded` instead of a stale
/// answer, while undeadlined work still completes exactly.
#[test]
fn slow_batches_expire_deadlines_with_a_typed_error() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};batch.slow=1:80", chaos_seed()), cfg);
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.25; 3 * 64]);
    // 2 ms deadline + 1 ms grace tick < the 80 ms injected stall
    let r = ask_dl(&mut c, &x, Duration::from_millis(2));
    let err = r.expect_err("an 80ms stall must expire a 2ms deadline");
    assert_eq!(err.code, ErrorCode::DeadlineExceeded, "got: {err}");
    assert!(server.stats().expired() >= 1);
    // no deadline: slow, but exact
    let r = ask(&mut c, MODEL, &x);
    let (y, _us) = r.expect("undeadlined request must complete");
    assert_bit_identical(&y, &reference(&x), "undeadlined");
    server.shutdown();
}

/// A full admission queue rejects with `Overloaded` instead of queueing
/// unboundedly; every client still gets an answer, and the retry client
/// rides the backoff to an eventual success.
#[test]
fn overload_sheds_with_typed_rejections_and_retry_recovers() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        max_batch: 1,
        queue_cap: 2,
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};batch.slow=1:50", chaos_seed()), cfg);
    let addr = server.local_addr();
    let x = Tensor::new(vec![1, 3, 8, 8], vec![-0.5; 3 * 64]);
    let want = reference(&x);

    let (mut oks, mut overloaded) = (0usize, 0usize);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..12)
            .map(|_| {
                let x = &x;
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    ask(&mut c, MODEL, x)
                })
            })
            .collect();
        for h in handles {
            match h.join().expect("client thread") {
                Ok((y, _us)) => {
                    assert_bit_identical(&y, &want, "admitted under overload");
                    oks += 1;
                }
                Err(e) => {
                    assert_eq!(e.code, ErrorCode::Overloaded, "got: {e}");
                    overloaded += 1;
                }
            }
        }
    });
    assert_eq!(oks + overloaded, 12, "every request was answered");
    assert!(overloaded >= 1, "a 12-client rush into a cap-2 queue must shed");
    assert!(server.stats().shed() >= 1);

    // a polite client with jittered backoff gets through the same storm
    let retry = RetryCfg {
        attempts: 10,
        backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(100),
        seed: chaos_seed(),
    };
    let mut c = Client::connect(addr).expect("connect");
    let r = c.predict_retry(MODEL, &x, Duration::ZERO, &retry);
    let (y, _us) = r.expect("backoff retry must eventually be admitted");
    assert_bit_identical(&y, &want, "retry");
    server.shutdown();
}

/// Torn response frames look like transport failures, never hangs: the
/// budgeted reader sees EOF, and a reconnecting retry client converges
/// on correct answers.
#[test]
fn torn_frames_are_survivable_transport_errors() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};frame.torn=0.5", chaos_seed()), cfg);
    let x = Tensor::new(vec![2, 3, 8, 8], vec![0.125; 2 * 3 * 64]);
    let want = reference(&x);
    let retry = RetryCfg {
        attempts: 10,
        backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(10),
        seed: chaos_seed(),
    };
    let mut c = Client::connect(server.local_addr()).expect("connect");
    for i in 0..12 {
        // predict_retry reconnects after each severed connection; a
        // torn frame may cost retries but never the answer
        let r = c.predict_retry(MODEL, &x, Duration::ZERO, &retry);
        let (y, _us) = r.unwrap_or_else(|e| panic!("request {i} lost to torn frames: {e}"));
        assert_bit_identical(&y, &want, &format!("request {i}"));
    }
    if let Some(f) = server.fault_plan() {
        assert!(f.injected(Site::Frame) >= 1, "prob-0.5 tearing must have fired");
    }
    server.shutdown();
}

/// Unknown models are a typed `ModelNotFound` on the wire — even with
/// resolve-site panics armed, the two failure modes stay distinct.
#[test]
fn unknown_models_are_model_not_found_not_panic() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};resolve.panic=0.3", chaos_seed()), cfg);
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::zeros(&[1, 3, 8, 8]);
    for _ in 0..10 {
        let r = ask(&mut c, "no-such-model", &x);
        let err = r.expect_err("unknown model must fail");
        let expected = matches!(err.code, ErrorCode::ModelNotFound | ErrorCode::Panic);
        assert!(expected, "got: {err}");
        if err.code == ErrorCode::ModelNotFound {
            assert!(err.message.contains("no-such-model"), "got: {err}");
        }
    }
    // the real model still resolves (or panics with the typed code) —
    // resolve faults never wedge the loop
    let mut survived = false;
    for _ in 0..50 {
        if ask(&mut c, MODEL, &x).is_ok() {
            survived = true;
            break;
        }
    }
    assert!(survived, "server must still serve the real model");
    server.shutdown();
}

/// The health verb reports live counters over the wire and flips
/// `draining` the moment a drain begins.
#[test]
fn health_verb_reports_counters_and_drain_state() {
    quiet_injected_panics();
    let server = Server::spawn(ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    })
    .expect("server spawn");
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::zeros(&[1, 3, 8, 8]);

    let h0 = c.health().expect("health");
    assert_eq!(h0.served, 0);
    assert!(!h0.draining);

    for _ in 0..3 {
        c.predict(MODEL, &x).expect("predict");
    }
    let h1 = c.health().expect("health");
    assert_eq!(h1.served, 3, "health verbs must not count as served");
    assert_eq!(h1.errors, 0);
    assert!(h1.batches >= 1);
    assert!(h1.cache_plans >= 1, "the plan cache holds the model");
    assert!(!h1.draining);

    server.begin_drain();
    let h2 = c.health().expect("health during drain");
    assert!(h2.draining, "drain must be visible over the wire");
    let r = ask(&mut c, MODEL, &x);
    let err = r.expect_err("draining server rejects predicts");
    assert_eq!(err.code, ErrorCode::ShuttingDown);
    server.drain();
}

/// The tentpole end-to-end: a server under concurrent client load is
/// live re-pruned over the wire. Zero requests are dropped, every
/// response is bit-identical to whichever plan generation served it,
/// and health reports the committed generation afterwards.
#[test]
fn live_swap_under_load_serves_every_request_exactly() {
    quiet_injected_panics();
    let server = Server::spawn(ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    })
    .expect("server spawn");
    let addr = server.local_addr();
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.5; 3 * 64]);
    let base = zoo::by_name(MODEL, image(), SEED).unwrap();
    let old_want = plan_predict(&base, &x);
    let new_want = plan_predict(&repruned(&base, 1.3), &x);

    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let (x, old_want, new_want, stop) = (&x, &old_want, &new_want, &stop);
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    let mut served = 0usize;
                    while !stop.load(Ordering::SeqCst) {
                        let (y, _us) =
                            ask(&mut c, MODEL, x).expect("no request may fail during a swap");
                        assert!(
                            bits_equal(&y, old_want) || bits_equal(&y, new_want),
                            "client {i}: response matches neither plan generation"
                        );
                        served += 1;
                    }
                    served
                })
            })
            .collect();

        // let the storm build, then re-prune over the wire mid-flight
        std::thread::sleep(Duration::from_millis(20));
        let mut cc = Client::connect(addr).expect("swap client");
        let rep = cc.swap(&swap_req(1.3, 4)).expect("swap transport");
        assert_eq!(rep.outcome, SwapOutcome::Committed, "{}", rep.message);
        assert_eq!((rep.from_generation, rep.to_generation), (1, 2));
        assert_eq!(rep.shadow_checked, 4, "the shadow gate must run live requests");
        assert!(rep.steps > 0);
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Ordering::SeqCst);
        let total: usize = handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .sum();
        assert!(total > 0, "the storm must have served requests");
    });

    // the flip is total: post-swap answers come from the new plan only
    let mut c = Client::connect(addr).expect("reconnect");
    let (y, _us) = ask(&mut c, MODEL, &x).expect("post-swap predict");
    assert_bit_identical(&y, &new_want, "post-swap");
    let h = c.health().expect("health");
    let entry = h
        .swaps
        .iter()
        .find(|e| e.key.contains(MODEL))
        .expect("health must report the swapped key");
    assert_eq!(entry.generation, 2);
    assert_eq!(entry.outcome, SwapOutcome::Committed);
    assert_eq!(server.stats().errors(), 0, "zero requests dropped or failed");
    server.shutdown();
}

/// An injected verification failure rolls the swap back before the
/// flip: the generation never advances and the old plan keeps serving
/// bit-identically.
#[test]
fn injected_verify_failure_rolls_back_before_the_flip() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};swap.verify_fail=1", chaos_seed()), cfg);
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.5; 3 * 64]);
    let want = reference(&x);
    let (y, _us) = ask(&mut c, MODEL, &x).expect("warmup");
    assert_bit_identical(&y, &want, "warmup");

    let rep = c.swap(&swap_req(1.3, 0)).expect("swap transport");
    assert_eq!(
        rep.outcome,
        SwapOutcome::RolledBack(SwapStage::Verify),
        "{}",
        rep.message
    );
    assert_eq!(rep.from_generation, 1);
    assert_eq!(rep.to_generation, 1, "a verify rollback must not advance");
    assert!(rep.message.contains("verification failed"), "got: {}", rep.message);

    let (y, _us) = ask(&mut c, MODEL, &x).expect("post-rollback predict");
    assert_bit_identical(&y, &want, "post-rollback");
    let h = c.health().expect("health");
    let entry = h.swaps.iter().find(|e| e.key.contains(MODEL)).expect("meta");
    assert_eq!(entry.generation, 1);
    assert_eq!(entry.outcome, SwapOutcome::RolledBack(SwapStage::Verify));
    server.shutdown();
}

/// An injected shadow divergence fails the parity gate: the candidate
/// is discarded pre-flip and the old generation keeps serving.
#[test]
fn injected_shadow_divergence_rolls_back_pre_flip() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};swap.shadow_diverge=1", chaos_seed()), cfg);
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::new(vec![1, 3, 8, 8], vec![-0.25; 3 * 64]);
    let want = reference(&x);
    let (y, _us) = ask(&mut c, MODEL, &x).expect("warmup");
    assert_bit_identical(&y, &want, "warmup");

    // the shadow stage only runs when the request asks for it
    let rep = c.swap(&swap_req(1.3, 4)).expect("swap transport");
    assert_eq!(
        rep.outcome,
        SwapOutcome::RolledBack(SwapStage::Shadow),
        "{}",
        rep.message
    );
    assert_eq!(rep.to_generation, 1, "a shadow rollback must not advance");
    assert!(rep.message.contains("shadow gate failed"), "got: {}", rep.message);

    let (y, _us) = ask(&mut c, MODEL, &x).expect("post-rollback predict");
    assert_bit_identical(&y, &want, "post-rollback");
    let h = c.health().expect("health");
    let entry = h.swaps.iter().find(|e| e.key.contains(MODEL)).expect("meta");
    assert_eq!(entry.generation, 1);
    assert_eq!(entry.outcome, SwapOutcome::RolledBack(SwapStage::Shadow));
    server.shutdown();
}

/// A panic spike right after the flip rolls the swap back to the old
/// generation automatically — the displaced plan is restored and serves
/// bit-identically once the monitor window closes.
#[test]
fn post_flip_panic_spike_rolls_back_to_the_old_generation() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(&format!("seed={};swap.post_flip_panic=1", chaos_seed()), cfg);
    let addr = server.local_addr();
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.125; 3 * 64]);
    let want = reference(&x);

    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let hammer = {
            let (x, stop) = (&x, &stop);
            s.spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                let mut panics = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    match ask(&mut c, MODEL, x) {
                        Ok(_) => {}
                        Err(e) => {
                            // only the injected post-flip panic may fail
                            assert_eq!(e.code, ErrorCode::Panic, "got: {e}");
                            panics += 1;
                        }
                    }
                }
                panics
            })
        };
        // traffic must be flowing so the post-flip monitor sees batches
        std::thread::sleep(Duration::from_millis(20));
        let rep = server.swap(&swap_req(1.3, 0)).expect("swap");
        assert_eq!(
            rep.outcome,
            SwapOutcome::RolledBack(SwapStage::PostFlip),
            "{}",
            rep.message
        );
        assert_eq!(
            rep.to_generation, rep.from_generation,
            "rollback must restore the old generation"
        );
        std::thread::sleep(Duration::from_millis(10));
        stop.store(true, Ordering::SeqCst);
        let panics = hammer.join().expect("hammer thread");
        assert!(panics >= 1, "the monitored window must record the injected panic");
    });

    let mut c = Client::connect(addr).expect("reconnect");
    let (y, _us) = ask(&mut c, MODEL, &x).expect("post-rollback predict");
    assert_bit_identical(&y, &want, "post-rollback");
    let h = c.health().expect("health");
    let entry = h.swaps.iter().find(|e| e.key.contains(MODEL)).expect("meta");
    assert_eq!(entry.generation, 1, "the restored generation serves");
    assert_eq!(entry.outcome, SwapOutcome::RolledBack(SwapStage::PostFlip));
    server.shutdown();
}

/// `predict_retry` rides through back-to-back live swaps without a
/// single lost request, and a genuinely draining server still surfaces
/// the typed `ShuttingDown` after the one reconnect the retry spends on
/// a presumed flip window.
#[test]
fn predict_retry_rides_through_swaps_and_still_sees_real_drains() {
    quiet_injected_panics();
    let server = Server::spawn(ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    })
    .expect("server spawn");
    let addr = server.local_addr();
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.75; 3 * 64]);
    // generation lineage: base, re-pruned at 1.3, then that re-pruned
    // at 1.5 (the second swap patches the already-pruned serving graph)
    let g1 = zoo::by_name(MODEL, image(), SEED).unwrap();
    let g2 = repruned(&g1, 1.3);
    let g3 = repruned(&g2, 1.5);
    let wants = [
        plan_predict(&g1, &x),
        plan_predict(&g2, &x),
        plan_predict(&g3, &x),
    ];
    let retry = RetryCfg {
        attempts: 6,
        backoff: Duration::from_millis(2),
        max_backoff: Duration::from_millis(20),
        seed: chaos_seed(),
    };

    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let client = {
            let (x, wants, stop, retry) = (&x, &wants, &stop, &retry);
            s.spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                let mut served = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    let (y, _us) = c
                        .predict_retry(MODEL, x, Duration::ZERO, retry)
                        .expect("predict_retry must ride through swaps");
                    assert!(
                        wants.iter().any(|w| bits_equal(&y, w)),
                        "response matches no known plan generation"
                    );
                    served += 1;
                }
                served
            })
        };
        std::thread::sleep(Duration::from_millis(10));
        for rf in [1.3, 1.5] {
            let rep = server.swap(&swap_req(rf, 0)).expect("swap");
            assert_eq!(rep.outcome, SwapOutcome::Committed, "{}", rep.message);
        }
        stop.store(true, Ordering::SeqCst);
        assert!(client.join().expect("client thread") > 0);
    });

    // a real drain is not a flip blip: after the single ShuttingDown
    // reconnect, the typed error surfaces instead of looping
    server.begin_drain();
    let mut c = Client::connect(addr).expect("connect");
    let err = c
        .predict_retry(MODEL, &x, Duration::ZERO, &retry)
        .expect_err("a draining server must surface ShuttingDown");
    let msg = err.to_string();
    assert!(
        msg.starts_with(ErrorCode::ShuttingDown.name()),
        "expected a shutting-down error, got: {msg}"
    );
    server.drain();
}

/// Observability must not observe itself into the results: with trace
/// recording on, plan outputs are bit-identical to untraced runs at
/// every thread width the determinism contract covers.
#[test]
fn traced_runs_are_bit_identical_to_untraced() {
    // toggles the process-global trace flag: hold the same lock the
    // par-override and obs unit tests use
    let _guard = spa::util::par::test_lock();
    let g = zoo::by_name(MODEL, image(), SEED).unwrap();
    let x = Tensor::new(vec![2, 3, 8, 8], vec![0.375; 2 * 3 * 64]);
    for threads in [1usize, 8] {
        spa::util::par::with_threads(threads, || {
            let want = plan_predict(&g, &x);
            spa::obs::trace::drain();
            spa::obs::ObsCfg::tracing().apply();
            let traced = plan_predict(&g, &x);
            spa::obs::ObsCfg::default().apply();
            let buf = spa::obs::trace::drain();
            assert_bit_identical(&traced, &want, &format!("threads={threads}"));
            assert!(
                buf.events.iter().any(|e| e.name == "exec.step"),
                "threads={threads}: a traced run must record step events"
            );
            assert!(
                buf.events.iter().any(|e| e.name == "exec.compile"),
                "threads={threads}: a traced compile must record itself"
            );
        });
    }
}

/// The protocol-v4 `metrics` verb must reconcile with the `health`
/// counters even after injected faults: panic totals, latency samples,
/// and swap outcomes all line up between the two snapshots.
#[test]
fn metrics_verb_reconciles_with_health_after_injected_faults() {
    let cfg = ServeCfg {
        tick: Duration::from_millis(1),
        image: image(),
        seed: SEED,
        ..Default::default()
    };
    let spec = format!("seed={};group.panic=0.4;swap.verify_fail=1", chaos_seed());
    let server = spawn(&spec, cfg);
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.5; 3 * 64]);
    for _ in 0..12 {
        // ok or a typed injected panic — both must land in the counters
        let _ = ask(&mut c, MODEL, &x);
    }
    // a forced verify failure lands in the rolled-back swap counter
    let rep = c.swap(&swap_req(1.3, 0)).expect("swap transport");
    assert_eq!(
        rep.outcome,
        SwapOutcome::RolledBack(SwapStage::Verify),
        "{}",
        rep.message
    );

    let h = c.health().expect("health");
    let m = c.metrics().expect("metrics");
    assert_eq!(m.served, h.served);
    assert_eq!(m.errors, h.errors);
    assert_eq!(m.batches, h.batches);
    assert_eq!(m.shed, h.shed);
    assert_eq!(m.expired, h.expired);
    assert_eq!(m.panics, h.panics);
    assert_eq!(m.cache_hits, h.cache_hits);
    assert_eq!(m.cache_misses, h.cache_misses);
    assert_eq!(m.draining, h.draining);
    assert_eq!(m.served, 12, "12 predicts, no control verbs counted");
    assert_eq!(m.lat_count, m.served, "one histogram sample per answered request");
    assert_eq!(m.p50_us, h.p50_us);
    assert_eq!(m.p99_us, h.p99_us);
    assert_eq!(m.p999_us, h.p999_us);
    assert_eq!(m.queue_wait_ns, h.queue_wait_ns);
    assert_eq!(m.exec_ns, h.exec_ns);
    assert!(m.p50_us > 0 && m.p50_us <= m.p99_us && m.p99_us <= m.p999_us);
    assert!(m.p999_us <= m.lat_max_us, "percentiles never exceed the exact max");
    assert!(m.lat_sum_us >= m.lat_max_us);

    // swap totals recomputed from health's per-key outcomes must match
    let committed = h
        .swaps
        .iter()
        .filter(|e| e.outcome == SwapOutcome::Committed)
        .count() as u64;
    let rolled = h
        .swaps
        .iter()
        .filter(|e| matches!(e.outcome, SwapOutcome::RolledBack(_)))
        .count() as u64;
    assert_eq!(m.swaps_committed, committed);
    assert_eq!(m.swaps_rolled_back, rolled);
    assert_eq!(rolled, 1, "the injected verify failure is the only swap");
    let max_gen = h.swaps.iter().map(|e| e.generation).max().unwrap_or(0);
    assert_eq!(m.generation, max_gen);
    assert!(m.swap_ns > 0, "the failed swap still spent wall time");
    server.shutdown();
}
