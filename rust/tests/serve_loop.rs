//! End-to-end serve-loop test over a loopback socket: a spawned server,
//! 8 concurrent clients with mixed soft deadlines, and responses gated
//! bit-identical against a local `Plan::predict` on the same zoo build.
//! Deadlines may only accelerate batch dispatch — every request must be
//! answered, at any `SPA_THREADS`.

use spa::exec::{Plan, PlanOpts};
use spa::serve::{protocol, Client, ErrorCode, FaultPlan, ServeCfg, Server};
use spa::tensor::Tensor;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use std::io::Write;
use std::sync::Arc;
use std::time::Duration;

const MODEL: &str = "mlp";
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 6;

fn image() -> ImageCfg {
    ImageCfg {
        channels: 3,
        hw: 8,
        classes: 10,
        batch: 8,
    }
}

#[test]
fn concurrent_clients_get_bit_identical_responses_and_deadlines_never_drop() {
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(5),
        max_batch: 32,
        cache_cap: 2,
        image: image(),
        seed: 3,
        ..Default::default()
    })
    .expect("server spawn");
    let addr = server.local_addr();

    // the reference: same zoo build + compile the server's resolver does
    let g = zoo::by_name(MODEL, image(), 3).unwrap();
    let plan = Plan::compile(&g, PlanOpts::default()).unwrap();

    // distinct per-client inputs with mixed request batch sizes (1..=3
    // rows) so one server batch stacks unequal leading dims
    let mut rng = Rng::new(11);
    let inputs: Vec<Tensor> = (0..CLIENTS)
        .map(|i| {
            let rows = 1 + i % 3;
            Tensor::new(
                vec![rows, 3, 8, 8],
                rng.uniform_vec(rows * 3 * 64, -1.0, 1.0),
            )
        })
        .collect();
    let want: Vec<Tensor> = inputs.iter().map(|x| plan.predict(x).unwrap()).collect();

    std::thread::scope(|s| {
        for (i, x) in inputs.iter().enumerate() {
            let want = &want[i];
            s.spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                for r in 0..REQS_PER_CLIENT {
                    // odd requests carry a soft deadline far below the
                    // tick: it accelerates dispatch, never drops
                    let (y, _server_us) = if r % 2 == 1 {
                        c.predict_deadline(MODEL, x, Duration::from_millis(1))
                            .expect("deadline predict")
                    } else {
                        c.predict(MODEL, x).expect("predict")
                    };
                    assert_eq!(y.shape, want.shape, "client {i} shape drift");
                    for (a, b) in y.data.iter().zip(&want.data) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "client {i} response must be bit-identical to Plan::predict"
                        );
                    }
                }
            });
        }
    });

    let stats = server.stats();
    assert_eq!(
        stats.served(),
        CLIENTS * REQS_PER_CLIENT,
        "every admitted request must be answered"
    );
    assert_eq!(stats.errors(), 0, "no request may fail or be dropped");
    assert!(stats.batches() >= 1);
    assert!(stats.latency_percentile_us(50.0).is_some());
    server.shutdown();
}

#[test]
fn malformed_model_errors_without_poisoning_the_connection() {
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(1),
        image: image(),
        seed: 3,
        ..Default::default()
    })
    .expect("server spawn");
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::zeros(&[1, 3, 8, 8]);
    assert!(c.predict("no-such-model", &x).is_err());
    // same connection keeps working after the error reply
    let (y, _us) = c.predict(MODEL, &x).expect("recover after error");
    assert_eq!(y.shape, vec![1, 10]);
    server.shutdown();
}

/// Regression: the server's 50 ms socket read timeout must only end
/// waits *between* frames. A healthy-but-slow client that dribbles one
/// request frame in across several timeout windows gets a normal
/// response, not a dropped connection mid-body.
#[test]
fn slow_client_dribbling_a_frame_is_not_disconnected() {
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(1),
        image: image(),
        seed: 3,
        ..Default::default()
    })
    .expect("server spawn");
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.25; 3 * 64]);
    let body = protocol::encode_request(MODEL, 0, &x).expect("encode");
    let mut stream = std::net::TcpStream::connect(server.local_addr()).expect("connect");
    // header, long pause (several 50 ms windows), half the body, pause,
    // the rest — every gap lands inside the frame
    let header = (body.len() as u32).to_le_bytes();
    stream.write_all(&header).unwrap();
    std::thread::sleep(Duration::from_millis(120));
    stream.write_all(&body[..body.len() / 2]).unwrap();
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(120));
    stream.write_all(&body[body.len() / 2..]).unwrap();
    stream.flush().unwrap();
    let reply = match protocol::read_frame(&mut stream).expect("server must respond") {
        protocol::FrameRead::Frame(b) => protocol::decode_response(&b).expect("decode"),
        _ => panic!("server dropped the slow client mid-frame"),
    };
    let y = match reply {
        spa::serve::Response::Ok { tensor, .. } => tensor,
        other => panic!("expected ok, got {other:?}"),
    };
    // and the answer is still the bit-identical prediction
    let g = zoo::by_name(MODEL, image(), 3).unwrap();
    let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
    let want = plan.predict(&x).unwrap();
    assert_eq!(y.shape, want.shape);
    for (a, b) in y.data.iter().zip(&want.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    server.shutdown();
}

/// Shutdown race: a client connected while the server drains gets a
/// typed `ShuttingDown` reply — never a hang, never a dead socket
/// without an answer.
#[test]
fn clients_during_drain_get_shutting_down_not_a_hang() {
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(1),
        image: image(),
        seed: 3,
        ..Default::default()
    })
    .expect("server spawn");
    let x = Tensor::zeros(&[1, 3, 8, 8]);
    // a connection from before the drain...
    let mut old = Client::connect(server.local_addr()).expect("connect");
    old.predict(MODEL, &x).expect("pre-drain predict");
    server.begin_drain();
    let r = old.try_predict(MODEL, &x, Duration::ZERO).expect("socket");
    let err = r.expect_err("drain must reject");
    assert_eq!(err.code, ErrorCode::ShuttingDown);
    // ...and a fresh connection during the drain: same typed answer
    let mut c2 = Client::connect(server.local_addr()).expect("connect during drain");
    let r = c2.try_predict(MODEL, &x, Duration::ZERO).expect("socket");
    let err = r.expect_err("drain must reject");
    assert_eq!(err.code, ErrorCode::ShuttingDown);
    // health still answers and reports the drain
    let health = c2.health().expect("health during drain");
    assert!(health.draining, "health must report draining");
    assert_eq!(health.served, 3, "pre-drain ok + two rejections");
    server.drain();
}

/// Shutdown race: dropping the `Server` with requests still in flight
/// (held up by an injected 150 ms slow batch) answers every one —
/// either the real bit-identical result or a typed `ShuttingDown`.
#[test]
fn dropping_the_server_answers_every_in_flight_request() {
    let faults = Arc::new(FaultPlan::parse("seed=1;batch.slow=1:150").expect("fault spec"));
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(5),
        image: image(),
        seed: 3,
        faults: Some(faults),
        ..Default::default()
    })
    .expect("server spawn");
    let addr = server.local_addr();
    let g = zoo::by_name(MODEL, image(), 3).unwrap();
    let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
    let x = Tensor::new(vec![1, 3, 8, 8], vec![0.5; 3 * 64]);
    let want = plan.predict(&x).unwrap();
    let workers: Vec<_> = (0..4)
        .map(|_| {
            let x = x.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                // the outer io::Result must survive the drop
                c.try_predict(MODEL, &x, Duration::ZERO).expect("socket")
            })
        })
        .collect();
    // let the requests land in the queue / the slow batch, then drop
    std::thread::sleep(Duration::from_millis(40));
    drop(server);
    for w in workers {
        match w.join().expect("worker must not hang or panic") {
            Ok((y, _us)) => {
                assert_eq!(y.shape, want.shape);
                for (a, b) in y.data.iter().zip(&want.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "flushed result must be exact");
                }
            }
            Err(e) => assert_eq!(e.code, ErrorCode::ShuttingDown, "got: {e}"),
        }
    }
}
