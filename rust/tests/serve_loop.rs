//! End-to-end serve-loop test over a loopback socket: a spawned server,
//! 8 concurrent clients with mixed soft deadlines, and responses gated
//! bit-identical against a local `Plan::predict` on the same zoo build.
//! Deadlines may only accelerate batch dispatch — every request must be
//! answered, at any `SPA_THREADS`.

use spa::exec::{Plan, PlanOpts};
use spa::serve::{Client, ServeCfg, Server};
use spa::tensor::Tensor;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use std::time::Duration;

const MODEL: &str = "mlp";
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 6;

fn image() -> ImageCfg {
    ImageCfg {
        channels: 3,
        hw: 8,
        classes: 10,
        batch: 8,
    }
}

#[test]
fn concurrent_clients_get_bit_identical_responses_and_deadlines_never_drop() {
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(5),
        max_batch: 32,
        cache_cap: 2,
        image: image(),
        seed: 3,
        ..Default::default()
    })
    .expect("server spawn");
    let addr = server.local_addr();

    // the reference: same zoo build + compile the server's resolver does
    let g = zoo::by_name(MODEL, image(), 3).unwrap();
    let plan = Plan::compile(&g, PlanOpts::default()).unwrap();

    // distinct per-client inputs with mixed request batch sizes (1..=3
    // rows) so one server batch stacks unequal leading dims
    let mut rng = Rng::new(11);
    let inputs: Vec<Tensor> = (0..CLIENTS)
        .map(|i| {
            let rows = 1 + i % 3;
            Tensor::new(
                vec![rows, 3, 8, 8],
                rng.uniform_vec(rows * 3 * 64, -1.0, 1.0),
            )
        })
        .collect();
    let want: Vec<Tensor> = inputs.iter().map(|x| plan.predict(x).unwrap()).collect();

    std::thread::scope(|s| {
        for (i, x) in inputs.iter().enumerate() {
            let want = &want[i];
            s.spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                for r in 0..REQS_PER_CLIENT {
                    // odd requests carry a soft deadline far below the
                    // tick: it accelerates dispatch, never drops
                    let (y, _server_us) = if r % 2 == 1 {
                        c.predict_deadline(MODEL, x, Duration::from_millis(1))
                            .expect("deadline predict")
                    } else {
                        c.predict(MODEL, x).expect("predict")
                    };
                    assert_eq!(y.shape, want.shape, "client {i} shape drift");
                    for (a, b) in y.data.iter().zip(&want.data) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "client {i} response must be bit-identical to Plan::predict"
                        );
                    }
                }
            });
        }
    });

    let stats = server.stats();
    assert_eq!(
        stats.served(),
        CLIENTS * REQS_PER_CLIENT,
        "every admitted request must be answered"
    );
    assert_eq!(stats.errors(), 0, "no request may fail or be dropped");
    assert!(stats.batches() >= 1);
    assert!(stats.latency_percentile_us(50.0).is_some());
    server.shutdown();
}

#[test]
fn malformed_model_errors_without_poisoning_the_connection() {
    let server = Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        tick: Duration::from_millis(1),
        image: image(),
        seed: 3,
        ..Default::default()
    })
    .expect("server spawn");
    let mut c = Client::connect(server.local_addr()).expect("connect");
    let x = Tensor::zeros(&[1, 3, 8, 8]);
    assert!(c.predict("no-such-model", &x).is_err());
    // same connection keeps working after the error reply
    let (y, _us) = c.predict(MODEL, &x).expect("recover after error");
    assert_eq!(y.shape, vec![1, 10]);
    server.shutdown();
}
