//! Proptest parity suite for the compiled-plan executor (`spa::exec`).
//!
//! Property: for a random zoo model, pruned to a random sparsity through
//! the `Session` API, a compiled `Plan` produces **bit-identical** logits
//! to `engine::forward` in `Mode::Eval` — at every worker-pool width
//! (`SPA_THREADS` ∈ {1, 4, 8}) and at a random batch size that differs
//! from the nominal compile-time shape.

use spa::criteria::Criterion;
use spa::engine::{self, Mode};
use spa::tensor::Tensor;
use spa::util::par;
use spa::util::proptest::check;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg, TextCfg};
use spa::{Session, Target};

const MODELS: &[&str] = &["mlp", "resnet18", "vgg16", "mobilenetv2", "densenet", "vit"];

fn bits_eq(a: &Tensor, b: &Tensor) -> Result<(), String> {
    if a.shape != b.shape {
        return Err(format!("shape {:?} vs {:?}", a.shape, b.shape));
    }
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("bit mismatch at {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn plan_parity_on_randomly_pruned_models() {
    let _serial = par::test_lock();
    let cfg = ImageCfg {
        hw: 8,
        ..Default::default()
    };
    check(
        "exec-parity",
        6,
        0xEC5E,
        |rng| {
            let name = MODELS[rng.below(MODELS.len())];
            let sparsity = 0.1 + 0.08 * rng.below(6) as f64;
            let batch = 1 + rng.below(5);
            (name.to_string(), sparsity, batch, rng.below(1 << 30) as u64)
        },
        |(name, sparsity, batch, seed)| {
            let g = zoo::by_name(name, cfg, *seed).map_err(|e| e.to_string())?;
            let pruned = Session::on(&g)
                .criterion(Criterion::L1)
                .target(Target::Sparsity(*sparsity))
                .plan()
                .map_err(|e| e.to_string())?
                .apply()
                .map_err(|e| e.to_string())?;
            pruned.graph.validate().map_err(|e| e.to_string())?;
            let mut rng = Rng::new(seed ^ 0x5A5A);
            let mut shape = pruned.graph.data(pruned.graph.inputs[0]).shape.clone();
            shape[0] = *batch;
            let n: usize = shape.iter().product();
            let x = Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0));
            let plan = pruned.compile().map_err(|e| e.to_string())?;
            let mut outs: Vec<Tensor> = Vec::new();
            for threads in [1usize, 4, 8] {
                let (want, got) = par::with_threads(threads, || {
                    let fwd = engine::forward(
                        &pruned.graph,
                        &[(pruned.graph.inputs[0], x.clone())],
                        Mode::Eval,
                    )
                    .unwrap();
                    let want = fwd.logits(&pruned.graph).clone();
                    let got = plan.predict(&x).unwrap();
                    (want, got)
                });
                bits_eq(&got, &want).map_err(|e| format!("{name} @ {threads} threads: {e}"))?;
                outs.push(got);
            }
            for o in &outs[1..] {
                bits_eq(o, &outs[0]).map_err(|e| format!("{name} across widths: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn plan_parity_on_pruned_distilbert() {
    let _serial = par::test_lock();
    let tcfg = TextCfg::default();
    let g = zoo::distilbert(tcfg, 17);
    let pruned = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::Sparsity(0.3))
        .plan()
        .unwrap()
        .apply()
        .unwrap();
    pruned.graph.validate().unwrap();
    let mut rng = Rng::new(99);
    let ids = Tensor::new(
        vec![4, tcfg.seq],
        (0..4 * tcfg.seq)
            .map(|_| rng.below(tcfg.vocab) as f32)
            .collect(),
    );
    let plan = pruned.compile().unwrap();
    let mut reference: Option<Tensor> = None;
    for threads in [1usize, 4, 8] {
        let (want, got) = par::with_threads(threads, || {
            let fwd = engine::forward(
                &pruned.graph,
                &[(pruned.graph.inputs[0], ids.clone())],
                Mode::Eval,
            )
            .unwrap();
            (fwd.logits(&pruned.graph).clone(), plan.predict(&ids).unwrap())
        });
        bits_eq(&got, &want).unwrap_or_else(|e| panic!("distilbert @ {threads}: {e}"));
        match &reference {
            None => reference = Some(got),
            Some(r) => bits_eq(&got, r).unwrap_or_else(|e| panic!("across widths: {e}")),
        }
    }
}
