//! Proptest: the inference-time rewrite pipeline never produces a graph
//! that fails static analysis.
//!
//! Property: for a random zoo model, `ir::passes::optimize_checked` at
//! `CheckLevel::Strict` — which re-runs `spa::check::check_graph` (shape
//! re-derivation + coupling invariants) after *every* individual pass —
//! succeeds, and its report is identical at worker-pool widths
//! `SPA_THREADS` ∈ {1, 8} (the house rule: results are independent of
//! parallelism).

use spa::check::{self, CheckLevel};
use spa::ir::passes;
use spa::util::par;
use spa::util::proptest::check as prop_check;
use spa::zoo::{self, ImageCfg, TextCfg};

const MODELS: &[&str] = &[
    "mlp",
    "alexnet",
    "resnet18",
    "vgg16",
    "mobilenetv2",
    "densenet",
    "regnet",
    "vit",
];

#[test]
fn optimize_pass_states_stay_statically_valid() {
    let _serial = par::test_lock();
    let cfg = ImageCfg {
        hw: 8,
        ..Default::default()
    };
    prop_check(
        "check-passes",
        8,
        0xC4EC,
        |rng| {
            let name = MODELS[rng.below(MODELS.len())];
            (name.to_string(), rng.below(1 << 30) as u64)
        },
        |(name, seed)| {
            let g0 = zoo::by_name(name, cfg, *seed).map_err(|e| e.to_string())?;
            check::check_graph(&g0).map_err(|e| format!("{name} pre-pass: {e}"))?;
            let mut reports = Vec::new();
            for threads in [1usize, 8] {
                let mut g = g0.clone();
                let rep = par::with_threads(threads, || {
                    passes::optimize_checked(&mut g, CheckLevel::Strict)
                })
                .map_err(|e| format!("{name} @ {threads} threads: {e}"))?;
                check::check_graph(&g)
                    .map_err(|e| format!("{name} @ {threads} threads post-pipeline: {e}"))?;
                reports.push(rep);
            }
            if reports[0] != reports[1] {
                return Err(format!(
                    "{name}: pass pipeline diverged across thread widths: {:?} vs {:?}",
                    reports[0], reports[1]
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn optimize_pass_states_stay_valid_on_distilbert() {
    let _serial = par::test_lock();
    let mut g = zoo::distilbert(TextCfg::default(), 11);
    passes::optimize_checked(&mut g, CheckLevel::Strict).unwrap();
    check::check_graph(&g).unwrap();
}
