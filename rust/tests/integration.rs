//! Cross-module integration tests: full pipelines over real zoo models,
//! dialect funnels feeding pipelines, serde round-trips of pruned graphs,
//! and property-based invariants over random graphs (mini-proptest).

use spa::analysis;
use spa::criteria::Criterion;
use spa::coordinator::{prune_train, train_prune_finetune, PipelineCfg};
use spa::data::ImageDataset;
use spa::engine;
use spa::frontends::{export_model, import_model, Dialect};
use spa::ir::{serde as ir_serde, Graph, GraphBuilder};
use spa::prune::{self, build_groups, score_groups, Agg, Norm};
use spa::tensor::Tensor;
use spa::train::TrainCfg;
use spa::util::proptest::check;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use std::collections::HashMap;

fn l1_scores(g: &Graph) -> HashMap<usize, Tensor> {
    g.param_ids()
        .into_iter()
        .map(|id| (id, g.data(id).param().unwrap().map(f32::abs)))
        .collect()
}

#[test]
fn dialect_to_pipeline_to_serde() {
    // tf-dialect resnet → import → train-prune-finetune → save → load → eval
    let icfg = ImageCfg {
        hw: 8,
        classes: 4,
        ..Default::default()
    };
    let ds = ImageDataset::synth_cifar(4, 256, 8, 3, 77);
    let src = zoo::resnet18(icfg, 5);
    let g = import_model(&export_model(&src, Dialect::Tf)).unwrap();
    let cfg = PipelineCfg {
        target_rf: 1.4,
        train: TrainCfg {
            steps: 40,
            ..Default::default()
        },
        finetune: TrainCfg {
            steps: 20,
            lr: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    let (pruned, rep) = train_prune_finetune(g, &ds, &cfg).unwrap();
    assert!(rep.rf >= 1.4);
    // round-trip the pruned model through the IR format
    let path = std::env::temp_dir().join("spa_integration_pruned.json");
    ir_serde::save_graph(&pruned, path.to_str().unwrap(), true).unwrap();
    let loaded = ir_serde::load_graph(path.to_str().unwrap()).unwrap();
    std::fs::remove_file(&path).ok();
    let mut rng = Rng::new(1);
    let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 3 * 64, -1.0, 1.0));
    let y1 = engine::predict(&pruned, x.clone()).unwrap();
    let y2 = engine::predict(&loaded, x).unwrap();
    spa::tensor::assert_allclose(&y2, &y1, 1e-5, 1e-5);
}

#[test]
fn snip_prune_train_on_mobilenet() {
    let icfg = ImageCfg {
        hw: 8,
        classes: 4,
        ..Default::default()
    };
    let ds = ImageDataset::synth_cifar(4, 256, 8, 3, 88);
    let g = zoo::mobilenetv2(icfg, 6);
    let cfg = PipelineCfg {
        criterion: Criterion::Snip.into(),
        target_rf: 1.3,
        train: TrainCfg {
            steps: 60,
            ..Default::default()
        },
        ..Default::default()
    };
    let (pruned, rep) = prune_train(g, &ds, &cfg).unwrap();
    pruned.validate().unwrap();
    assert!(rep.rf >= 1.3);
    assert!(rep.final_acc > 0.3, "final {}", rep.final_acc);
}

// ---- property-based invariants over random residual graphs -------------

/// Generate a random conv net with optional residuals/concats/group convs.
fn random_graph(rng: &mut Rng) -> Graph {
    let mut b = GraphBuilder::new("rand", rng.next_u64());
    let ch0 = [4usize, 6, 8][rng.below(3)];
    let x = b.input("x", vec![1, 3, 8, 8]);
    let mut h = b.conv2d("stem", x, ch0, 3, 1, 1, 1, false);
    let mut ch = ch0;
    let blocks = 1 + rng.below(3);
    for i in 0..blocks {
        match rng.below(3) {
            0 => {
                // residual pair
                let c1 = b.conv2d(&format!("b{i}a"), h, ch, 3, 1, 1, 1, false);
                let n1 = b.batchnorm(&format!("b{i}bn"), c1);
                let r1 = b.relu(&format!("b{i}r"), n1);
                let c2 = b.conv2d(&format!("b{i}b"), r1, ch, 3, 1, 1, 1, false);
                h = b.add(&format!("b{i}add"), c2, h);
            }
            1 => {
                // concat growth
                let c1 = b.conv2d(&format!("b{i}g"), h, 4, 3, 1, 1, 1, false);
                h = b.concat(&format!("b{i}cat"), &[h, c1], 1);
                ch += 4;
            }
            _ => {
                // grouped conv (groups divide both in and out)
                let groups = if ch % 2 == 0 { 2 } else { 1 };
                let co = ch;
                h = b.conv2d(&format!("b{i}grp"), h, co, 3, 1, 1, groups, false);
            }
        }
    }
    let g = b.global_avgpool("gap", h);
    let out = b.gemm("head", g, 3, false);
    b.output(out);
    b.finish().expect("random graph")
}

#[test]
fn prop_random_graphs_prune_and_run() {
    check(
        "random-graph-prunes-validly",
        12,
        0xBEEF,
        |rng| random_graph(rng),
        |g| {
            let groups = build_groups(g).map_err(|e| e.to_string())?;
            let scores = score_groups(g, &groups, &l1_scores(g), Agg::Sum, Norm::Mean);
            let sel = prune::select_lowest(&groups, &scores, 0.4, 1);
            if sel.is_empty() {
                return Ok(());
            }
            let mut pruned = g.clone();
            prune::apply_pruning(&mut pruned, &groups, &sel).map_err(|e| e.to_string())?;
            pruned.validate().map_err(|e| e.to_string())?;
            // FLOPs monotone
            if analysis::flops(&pruned) >= analysis::flops(g) {
                return Err("flops did not decrease".into());
            }
            // still executes with finite outputs
            let mut rng2 = Rng::new(1);
            let x = Tensor::new(vec![1, 3, 8, 8], rng2.uniform_vec(3 * 64, -1.0, 1.0));
            let y = engine::predict(&pruned, x).map_err(|e| e.to_string())?;
            if !y.data.iter().all(|v| v.is_finite()) {
                return Err("non-finite output".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_propagation_coupling_is_symmetric() {
    use spa::prune::rules::{param_locs, propagate, Mask};
    check(
        "coupling-symmetry",
        8,
        0xCAFE,
        |rng| {
            let g = random_graph(rng);
            // pick a random conv weight + channel
            let convs: Vec<usize> = g
                .datas
                .iter()
                .filter(|d| d.is_param() && d.shape.len() == 4)
                .map(|d| d.id)
                .collect();
            let w = convs[rng.below(convs.len())];
            let c = rng.below(g.data(w).shape[0]);
            (g, w, c)
        },
        |(g, w, c)| {
            let m1 = propagate(g, Mask::single(g, *w, 0, *c));
            let locs1 = param_locs(g, &m1);
            // symmetry: re-propagating from any coupled source loc yields
            // the same coupled set
            for loc in locs1.iter().take(3) {
                if !g.data(loc.data).is_param() {
                    continue;
                }
                let m2 = propagate(g, Mask::single(g, loc.data, loc.dim, loc.idx));
                let locs2 = param_locs(g, &m2);
                if locs2 != locs1 {
                    return Err(format!(
                        "asymmetric coupling from {:?}: {} vs {} locs",
                        loc,
                        locs2.len(),
                        locs1.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pruned_params_strictly_decrease() {
    check(
        "params-monotone",
        10,
        0xD00D,
        |rng| random_graph(rng),
        |g| {
            let groups = build_groups(g).map_err(|e| e.to_string())?;
            let scores = score_groups(g, &groups, &l1_scores(g), Agg::Sum, Norm::Mean);
            let sel = prune::select_lowest(&groups, &scores, 0.3, 1);
            if sel.is_empty() {
                return Ok(());
            }
            let mut pruned = g.clone();
            prune::apply_pruning(&mut pruned, &groups, &sel).map_err(|e| e.to_string())?;
            if pruned.num_params() >= g.num_params() {
                return Err("params did not decrease".into());
            }
            Ok(())
        },
    );
}
