//! Edge-case and failure-injection tests for the pruning stack: malformed
//! selections, extreme ratios, degenerate graphs, serde of pruned +
//! BN-folded models, and criteria behaviour on pathological weights.

use spa::analysis;
use spa::criteria::{self, Batch, Criterion};
use spa::engine;
use spa::ir::{passes, serde as ir_serde, GraphBuilder};
use spa::prune::{self, build_groups, score_groups, Agg, Norm};
use spa::tensor::Tensor;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use std::collections::HashMap;

fn l1(g: &spa::ir::Graph) -> HashMap<usize, Tensor> {
    g.param_ids()
        .into_iter()
        .map(|id| (id, g.data(id).param().unwrap().map(f32::abs)))
        .collect()
}

#[test]
fn extreme_ratio_is_capped_by_min_keep() {
    // asking for RF 1000x must not destroy the network: min_keep floors it
    let g = zoo::resnet18(ImageCfg { hw: 8, ..Default::default() }, 1);
    let groups = build_groups(&g).unwrap();
    let ranked = score_groups(&g, &groups, &l1(&g), Agg::Sum, Norm::Mean);
    let sel = prune::select_by_flops_target(&g, &groups, &ranked, 1000.0, 2).unwrap();
    let mut pruned = g.clone();
    prune::apply_pruning(&mut pruned, &groups, &sel).unwrap();
    pruned.validate().unwrap();
    // every conv keeps >= 2 channels
    for d in &pruned.datas {
        if d.name.ends_with(".w") && d.shape.len() == 4 {
            assert!(d.shape[0] >= 2, "{} over-pruned: {:?}", d.name, d.shape);
        }
    }
    // and it still runs
    let mut rng = Rng::new(2);
    let x = Tensor::new(vec![1, 3, 8, 8], rng.uniform_vec(192, -1.0, 1.0));
    engine::predict(&pruned, x).unwrap();
}

#[test]
fn duplicate_selection_is_idempotent() {
    let g = zoo::vgg16(ImageCfg { hw: 8, ..Default::default() }, 2);
    let groups = build_groups(&g).unwrap();
    let gid = groups.groups.iter().find(|gr| gr.prunable).unwrap().id;
    let mut a = g.clone();
    prune::apply_pruning(&mut a, &groups, &[(gid, 0), (gid, 0)]).unwrap();
    let mut b = g.clone();
    prune::apply_pruning(&mut b, &groups, &[(gid, 0)]).unwrap();
    assert_eq!(a.num_params(), b.num_params());
}

#[test]
fn zero_selection_is_noop() {
    let g = zoo::resnet18(ImageCfg { hw: 8, ..Default::default() }, 3);
    let mut pruned = g.clone();
    let groups = build_groups(&g).unwrap();
    prune::apply_pruning(&mut pruned, &groups, &[]).unwrap();
    assert_eq!(g.num_params(), pruned.num_params());
    assert_eq!(analysis::flops(&g), analysis::flops(&pruned));
}

#[test]
fn single_channel_layers_never_vanish() {
    // a bottleneck squeezed to width 2: pruning keeps the graph connected
    let mut b = GraphBuilder::new("narrow", 4);
    let x = b.input("x", vec![1, 3, 6, 6]);
    let c1 = b.conv2d("c1", x, 2, 3, 1, 1, 1, false);
    let c2 = b.conv2d("c2", c1, 8, 3, 1, 1, 1, false);
    let gp = b.global_avgpool("gap", c2);
    let out = b.gemm("fc", gp, 2, false);
    b.output(out);
    let g = b.finish().unwrap();
    let groups = build_groups(&g).unwrap();
    let ranked = score_groups(&g, &groups, &l1(&g), Agg::Sum, Norm::Mean);
    let sel = prune::select_lowest(&groups, &ranked, 1.0, 1);
    let mut pruned = g.clone();
    prune::apply_pruning(&mut pruned, &groups, &sel).unwrap();
    let c1w = pruned.data_by_name("c1.w").unwrap();
    assert!(c1w.shape[0] >= 1);
    pruned.validate().unwrap();
}

#[test]
fn pruned_then_folded_then_serialized_round_trips() {
    // compose everything: prune → BN-fold → save → load → same numerics
    let mut g = zoo::resnet18(ImageCfg { hw: 8, ..Default::default() }, 5);
    // randomize stats so folding is non-trivial
    let mut rng = Rng::new(6);
    for d in &mut g.datas {
        let name = d.name.clone();
        if let Some(t) = d.param_mut() {
            if name.ends_with(".var") {
                t.data = rng.uniform_vec(t.numel(), 0.5, 2.0);
            }
        }
    }
    let groups = build_groups(&g).unwrap();
    let ranked = score_groups(&g, &groups, &l1(&g), Agg::Sum, Norm::Mean);
    let sel = prune::select_lowest(&groups, &ranked, 0.3, 1);
    prune::apply_pruning(&mut g, &groups, &sel).unwrap();
    passes::fold_batchnorm(&mut g).unwrap();
    let x = Tensor::new(vec![1, 3, 8, 8], rng.uniform_vec(192, -1.0, 1.0));
    let before = engine::predict(&g, x.clone()).unwrap();
    let path = std::env::temp_dir().join("spa_edge_roundtrip.json");
    ir_serde::save_graph(&g, path.to_str().unwrap(), true).unwrap();
    let loaded = ir_serde::load_graph(path.to_str().unwrap()).unwrap();
    std::fs::remove_file(&path).ok();
    let after = engine::predict(&loaded, x).unwrap();
    spa::tensor::assert_allclose(&after, &before, 1e-5, 1e-5);
}

#[test]
fn criteria_handle_all_zero_weights() {
    // degenerate: a model whose conv weights are all zero must still
    // score/select/prune without NaNs or panics
    let mut g = zoo::vgg16(ImageCfg { hw: 8, ..Default::default() }, 7);
    for d in &mut g.datas {
        let name = d.name.clone();
        if let Some(t) = d.param_mut() {
            if name.ends_with(".w") {
                t.data.iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }
    let groups = build_groups(&g).unwrap();
    let ranked = score_groups(&g, &groups, &l1(&g), Agg::Sum, Norm::Mean);
    assert!(ranked.iter().all(|s| s.score.is_finite()));
    let sel = prune::select_lowest(&groups, &ranked, 0.3, 1);
    let mut pruned = g.clone();
    prune::apply_pruning(&mut pruned, &groups, &sel).unwrap();
    pruned.validate().unwrap();
}

#[test]
fn fisher_criterion_scores_are_finite_and_nonneg() {
    let g = zoo::resnet18(ImageCfg { hw: 8, classes: 4, ..Default::default() }, 8);
    let mut rng = Rng::new(9);
    let x = Tensor::new(vec![4, 3, 8, 8], rng.uniform_vec(4 * 192, -1.0, 1.0));
    let labels: Vec<usize> = (0..4).map(|_| rng.below(4)).collect();
    let scores =
        criteria::param_scores(&g, Criterion::Fisher, Some(&Batch { x: &x, labels: &labels }))
            .unwrap();
    for (_, t) in scores {
        assert!(t.data.iter().all(|v| v.is_finite() && *v >= 0.0));
    }
}

#[test]
fn obspa_rejects_oversized_layers_gracefully() {
    // kdim beyond the ladder must fall back to native, not error
    use spa::runtime::kernels as rk;
    let mut rng = Rng::new(10);
    let c = 600usize; // > 512 ladder max
    let w = Tensor::new(vec![4, c], rng.uniform_vec(4 * c, -1.0, 1.0));
    let mut h = Tensor::zeros(&[c, c]);
    for i in 0..c {
        h.data[i * c + i] = 1.0;
    }
    let sweep = rk::sweep_matrix(&h).unwrap();
    let mask = vec![0.0f32; c];
    let (out, backend) = rk::obs_update(&w, &sweep, &mask).unwrap();
    assert_eq!(backend, rk::Backend::Native);
    spa::tensor::assert_allclose(&out, &w, 1e-5, 1e-5);
}

#[test]
fn importance_norms_keep_relative_order_within_group() {
    // Norm rescales but must not reorder CCs within a group
    let g = zoo::vgg16(ImageCfg { hw: 8, ..Default::default() }, 11);
    let groups = build_groups(&g).unwrap();
    let scores = l1(&g);
    let base = score_groups(&g, &groups, &scores, Agg::Sum, Norm::None);
    for norm in [Norm::Sum, Norm::Mean, Norm::Max] {
        let normed = score_groups(&g, &groups, &scores, Agg::Sum, norm);
        // group-wise order preserved
        use std::collections::HashMap as Map;
        let mut by_group: Map<usize, Vec<(usize, f32, f32)>> = Map::new();
        for (a, b) in base.iter().zip(&normed) {
            assert_eq!((a.group, a.cc), (b.group, b.cc));
            by_group.entry(a.group).or_default().push((a.cc, a.score, b.score));
        }
        for (_, mut v) in by_group {
            v.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
            for w in v.windows(2) {
                assert!(
                    w[0].2 <= w[1].2 + 1e-6,
                    "norm {norm:?} reordered scores"
                );
            }
        }
    }
}
