//! Integration tests for the PJRT artifact path: the Rust engine and the
//! JAX-lowered executables must agree numerically, and the Pallas OBSPA
//! kernel must match the native fallback bit-for-bit (within fp32 noise).
//!
//! Tests that need artifacts skip gracefully when `make artifacts` has
//! not been run (CI always runs it via `make test`).

use spa::runtime::{kernels as rk, Runtime, M_BLOCK, ROW_BLOCK};
use spa::tensor::{assert_allclose, ops, Tensor};
use spa::util::Rng;

fn runtime() -> Option<std::rc::Rc<Runtime>> {
    let rt = Runtime::global();
    if rt.is_none() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    rt
}

/// Mirror of python/compile/aot.py MODEL_SHAPES.
const BATCH: usize = 4;
const CIN: usize = 3;
const HW: usize = 8;
const COUT: usize = 8;
const CLASSES: usize = 10;

#[test]
fn model_fwd_artifact_matches_engine() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(42);
    let x = Tensor::new(
        vec![BATCH, CIN, HW, HW],
        rng.uniform_vec(BATCH * CIN * HW * HW, -1.0, 1.0),
    );
    let w = Tensor::new(
        vec![COUT, CIN, 3, 3],
        rng.uniform_vec(COUT * CIN * 9, -0.3, 0.3),
    );
    let b = Tensor::new(vec![COUT], rng.uniform_vec(COUT, -0.1, 0.1));
    let wf = Tensor::new(
        vec![CLASSES, COUT],
        rng.uniform_vec(CLASSES * COUT, -0.3, 0.3),
    );
    let bf = Tensor::zeros(&[CLASSES]);
    // PJRT path (JAX-lowered HLO)
    let outs = rt
        .execute("model_fwd", &[&x, &w, &b, &wf, &bf])
        .expect("model_fwd artifact must execute");
    // native engine path: same computation
    let conv = ops::conv2d(&x, &w, Some(&b), 1, 1, 1);
    let relu = conv.map(|v| v.max(0.0));
    let pooled = ops::global_avgpool(&relu);
    let logits = ops::linear(&pooled, &wf, Some(&bf));
    assert_eq!(outs.len(), 1);
    assert_allclose(&outs[0], &logits, 1e-4, 1e-4);
}

#[test]
fn obs_update_pjrt_matches_native() {
    let Some(_rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    for &c in &[16usize, 48, 100] {
        let r = 20usize;
        let w = Tensor::new(vec![r, c], rng.uniform_vec(r * c, -1.0, 1.0));
        // SPD → sweep matrix, as the solver does
        let x = Tensor::new(vec![c, c + 8], rng.uniform_vec(c * (c + 8), -1.0, 1.0));
        let mut h = ops::matmul(&x, &x.t2());
        for i in 0..c {
            h.data[i * c + i] += 0.5;
        }
        let sweep = rk::sweep_matrix(&h).unwrap();
        let mut mask = vec![0.0f32; c];
        for i in (0..c).step_by(3) {
            mask[i] = 1.0;
        }
        let native = rk::obs_update_native(&w, &sweep, &mask);
        let (pjrt, backend) = rk::obs_update(&w, &sweep, &mask).unwrap();
        assert_eq!(backend, rk::Backend::Pjrt, "artifacts exist → PJRT path");
        assert_allclose(&pjrt, &native, 5e-3, 5e-3);
    }
}

#[test]
fn hessian_pjrt_matches_native() {
    let Some(_rt) = runtime() else { return };
    let mut rng = Rng::new(8);
    for &(c, m) in &[(16usize, 64usize), (40, 200), (128, M_BLOCK)] {
        let h0 = Tensor::new(vec![c, c], rng.uniform_vec(c * c, -0.2, 0.2));
        // symmetrize
        let mut h0s = h0.clone();
        for i in 0..c {
            for j in 0..c {
                h0s.data[i * c + j] = 0.5 * (h0.data[i * c + j] + h0.data[j * c + i]);
            }
        }
        let x = Tensor::new(vec![c, m], rng.uniform_vec(c * m, -1.0, 1.0));
        let native = rk::hessian_accum_native(&h0s, &x);
        let (pjrt, backend) = rk::hessian_accum(&h0s, &x).unwrap();
        assert_eq!(backend, rk::Backend::Pjrt);
        assert_allclose(&pjrt, &native, 1e-3, 1e-3);
    }
}

#[test]
fn obs_update_row_padding_is_exact() {
    let Some(_rt) = runtime() else { return };
    // rows not a multiple of ROW_BLOCK force padding inside the kernel call
    let mut rng = Rng::new(9);
    let (r, c) = (ROW_BLOCK + 17, 32usize);
    let w = Tensor::new(vec![r, c], rng.uniform_vec(r * c, -1.0, 1.0));
    let x = Tensor::new(vec![c, c + 8], rng.uniform_vec(c * (c + 8), -1.0, 1.0));
    let mut h = ops::matmul(&x, &x.t2());
    for i in 0..c {
        h.data[i * c + i] += 0.5;
    }
    let sweep = rk::sweep_matrix(&h).unwrap();
    let mut mask = vec![0.0f32; c];
    mask[5] = 1.0;
    mask[20] = 1.0;
    let native = rk::obs_update_native(&w, &sweep, &mask);
    let (pjrt, _) = rk::obs_update(&w, &sweep, &mask).unwrap();
    assert_allclose(&pjrt, &native, 5e-3, 5e-3);
}
