//! Tests for the `spa::Session` staged pruning API: staging misuse,
//! every `Target` variant on a resnet-mini, clamped unreachable targets,
//! and a user-registered `Saliency` impl round-tripping through
//! `Criterion::parse`.

use spa::criteria::{self, Batch, Criterion, Saliency, SaliencyRef};
use spa::ir::{DataId, Graph};
use spa::tensor::Tensor;
use spa::zoo::{self, ImageCfg};
use spa::{Session, Target};
use std::collections::HashMap;

fn mini(seed: u64) -> Graph {
    zoo::resnet18(
        ImageCfg {
            hw: 8,
            ..Default::default()
        },
        seed,
    )
}

#[test]
fn plan_before_criterion_is_a_staging_error() {
    let g = mini(1);
    let err = Session::on(&g)
        .target(Target::FlopsRf(2.0))
        .plan()
        .unwrap_err();
    assert!(
        err.to_string().contains("criterion"),
        "error should name the missing stage: {err}"
    );
}

#[test]
fn gradient_criterion_without_batch_is_a_staging_error() {
    let g = mini(2);
    let err = Session::on(&g).criterion(Criterion::Snip).plan().unwrap_err();
    assert!(
        err.to_string().contains("batch"),
        "error should ask for a batch: {err}"
    );
}

#[test]
fn target_flops_rf_hits_ratio() {
    let g = mini(3);
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::FlopsRf(1.7))
        .plan()
        .unwrap();
    assert!(!plan.clamped);
    assert!(plan.achieved_rf >= 1.7, "rf {}", plan.achieved_rf);
    assert!(plan.achieved_rf < 3.5, "rf {} wildly above target", plan.achieved_rf);
    let pruned = plan.apply().unwrap();
    pruned.graph.validate().unwrap();
    assert!((pruned.report.rf - plan.achieved_rf).abs() < 1e-9);
}

#[test]
fn target_params_rp_hits_ratio() {
    let g = mini(4);
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::ParamsRp(1.3))
        .plan()
        .unwrap();
    assert!(!plan.clamped);
    assert!(plan.achieved_rp >= 1.3, "rp {}", plan.achieved_rp);
    plan.apply().unwrap().graph.validate().unwrap();
}

#[test]
fn target_sparsity_selects_the_requested_fraction() {
    let g = mini(5);
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::Sparsity(0.3))
        .plan()
        .unwrap();
    let expect = ((plan.num_prunable_ccs() as f64) * 0.3).round() as usize;
    assert_eq!(plan.num_selected(), expect);
    assert!(!plan.clamped);
    plan.apply().unwrap().graph.validate().unwrap();
}

#[test]
fn target_channel_budget_is_exact() {
    let g = mini(6);
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::ChannelBudget(7))
        .plan()
        .unwrap();
    assert_eq!(plan.num_selected(), 7);
    assert!(!plan.clamped);
    let pruned = plan.apply().unwrap();
    assert_eq!(pruned.report.ccs_removed, 7);
    pruned.graph.validate().unwrap();
    // an infeasible budget is clamped and flagged
    let greedy = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::ChannelBudget(1_000_000))
        .plan()
        .unwrap();
    assert!(greedy.clamped);
    assert!(greedy.num_selected() < 1_000_000);
}

#[test]
fn unreachable_flops_target_is_clamped_and_surfaced() {
    let g = mini(7);
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .min_keep(2)
        .target(Target::FlopsRf(1000.0))
        .plan()
        .unwrap();
    assert!(plan.clamped, "RF 1000x is infeasible under min_keep");
    assert!(plan.achieved_rf > 1.0 && plan.achieved_rf < 1000.0);
    let pruned = plan.apply().unwrap();
    pruned.graph.validate().unwrap();
    // min_keep floors survive
    for d in &pruned.graph.datas {
        if d.name.ends_with(".w") && d.shape.len() == 4 {
            assert!(d.shape[0] >= 2, "{} over-pruned: {:?}", d.name, d.shape);
        }
    }
}

#[test]
fn plan_is_inspectable_before_apply() {
    let g = mini(8);
    let plan = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::Sparsity(0.2))
        .plan()
        .unwrap();
    assert_eq!(plan.criterion(), "l1");
    assert_eq!(plan.target(), Target::Sparsity(0.2));
    assert!(plan.num_groups() > 0);
    assert_eq!(plan.scores().len(), plan.num_prunable_ccs());
    // every selected CC refers to a real group/cc pair
    for &(gid, cc) in plan.selected() {
        let group = &plan.groups().groups[gid];
        assert!(group.prunable);
        assert!(cc < group.ccs.len());
    }
}

/// A user criterion: saliency = channel index (prunes low-index channels
/// first). Deliberately trivial so selection order is predictable.
struct ChannelIndex;

impl Saliency for ChannelIndex {
    fn name(&self) -> &str {
        "channel-index"
    }

    fn score(
        &self,
        g: &Graph,
        _batch: Option<&Batch>,
    ) -> anyhow::Result<HashMap<DataId, Tensor>> {
        Ok(g.param_ids()
            .into_iter()
            .map(|id| {
                let shape = g.data(id).shape.clone();
                let mut s = Tensor::zeros(&shape);
                for (i, v) in s.data.iter_mut().enumerate() {
                    *v = i as f32;
                }
                (id, s)
            })
            .collect())
    }
}

#[test]
fn custom_saliency_roundtrips_through_parse() {
    criteria::register(SaliencyRef::new(ChannelIndex)).unwrap();
    let resolved = Criterion::parse("channel-index").unwrap();
    assert_eq!(resolved.name(), "channel-index");
    assert!(!resolved.needs_data());
    let g = mini(9);
    let plan = Session::on(&g)
        .criterion(resolved)
        .target(Target::Sparsity(0.2))
        .plan()
        .unwrap();
    assert_eq!(plan.criterion(), "channel-index");
    assert!(plan.num_selected() > 0);
    let pruned = plan.apply().unwrap();
    pruned.graph.validate().unwrap();
    assert_eq!(pruned.report.criterion, "channel-index");
    // and the registry still rejects shadowing
    assert!(criteria::register(SaliencyRef::new(ChannelIndex)).is_err());
}

#[test]
fn session_batch_feeds_gradient_criteria() {
    let g = zoo::resnet18(
        ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        },
        10,
    );
    let ds = spa::data::ImageDataset::synth_cifar(4, 128, 8, 3, 11);
    let (x, labels) = ds.train_batch_seeded(1, 16);
    let plan = Session::on(&g)
        .criterion(Criterion::Snip)
        .batch(x, labels)
        .target(Target::FlopsRf(1.4))
        .plan()
        .unwrap();
    assert!(plan.achieved_rf >= 1.4);
    plan.apply().unwrap().graph.validate().unwrap();
}
