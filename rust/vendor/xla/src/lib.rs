//! Offline stub of the `xla-rs` PJRT binding surface used by
//! `spa::runtime`.
//!
//! The build environment has no XLA shared library and no network, so the
//! `pjrt` feature links this stub instead: every entry point type-checks
//! against the real API, and [`PjRtClient::cpu`] returns an error, which
//! makes `spa::runtime::Runtime::global()` resolve to `None` and every
//! kernel fall back to the bit-exact native path. Swapping this path
//! dependency for a real `xla` build re-enables artifact execution with
//! no source changes.

use std::fmt;
use std::marker::PhantomData;

/// Error type mirroring `xla_rs::Error` for the stubbed calls.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: xla stub — no PJRT runtime linked in this build environment"
    ))
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Stub PJRT client; construction always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub device buffer returned by executions.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: BufferInput>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Marker trait for types accepted as execution inputs.
pub trait BufferInput {}

impl BufferInput for Literal {}

/// Stub array shape (dims only).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Element types a literal can be read back as.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i64 {}

/// Stub host literal.
pub struct Literal {
    _private: PhantomData<()>,
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal {
            _private: PhantomData,
        }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("stub"));
    }
}
