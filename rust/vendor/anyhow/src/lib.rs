//! Vendored, std-only subset of the `anyhow` error-handling API.
//!
//! The build environment is offline with no crates.io registry, so the
//! workspace vendors the small slice of `anyhow` it actually uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//! Semantics match upstream for that slice: any `std::error::Error` value
//! converts into [`Error`] via `?`, and `anyhow!` builds an error from a
//! format string.

use std::fmt;

/// A dynamically-typed error with a human-readable message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let plain: Error = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let formatted: Error = anyhow!("x = {}", 7);
        assert_eq!(formatted.to_string(), "x = 7");
        let f = || -> Result<()> { bail!("bailed {}", 1) };
        assert_eq!(f().unwrap_err().to_string(), "bailed 1");
        let g = |ok: bool| -> Result<()> {
            ensure!(ok, "must be ok");
            Ok(())
        };
        assert!(g(true).is_ok());
        assert_eq!(g(false).unwrap_err().to_string(), "must be ok");
    }
}
