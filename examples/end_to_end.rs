//! End-to-end driver (the session's required full-system validation):
//! train a ResNet-mini on SynthCIFAR-10 for a few hundred steps with the
//! loss curve logged, prune it 2× with SPA-L1, fine-tune, and run OBSPA
//! on the same base model for comparison — all three layers composing:
//! L3 pipelines + IR engine, and OBSPA's PJRT-executed Pallas kernels
//! (when `make artifacts` has run).
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! The run recorded in EXPERIMENTS.md §End-to-end was produced by exactly
//! this binary.

use spa::coordinator::{train_prune, train_prune_finetune, NoFinetuneAlgo, PipelineCfg};
use spa::criteria::Criterion;
use spa::data::ImageDataset;
use spa::obspa::CalibSource;
use spa::runtime::Runtime;
use spa::train::TrainCfg;
use spa::util::Table;
use spa::zoo::{self, ImageCfg};

fn main() -> anyhow::Result<()> {
    match Runtime::global() {
        Some(rt) => println!("PJRT runtime: {} (Pallas artifacts loaded)", rt.platform()),
        None => println!("PJRT artifacts not found — OBSPA uses the native fallback"),
    }

    let icfg = ImageCfg {
        hw: 16,
        classes: 10,
        ..Default::default()
    };
    let ds = ImageDataset::synth_cifar(10, 2048, icfg.hw, icfg.channels, 1234);
    let model = zoo::resnet18(icfg, 7);
    println!(
        "\n=== phase 1: train + SPA-L1 prune 2x + finetune ({} params) ===",
        model.num_params()
    );
    let cfg = PipelineCfg {
        criterion: Criterion::L1.into(),
        target_rf: 2.0,
        train: TrainCfg {
            steps: 300,
            lr: 0.05,
            log_every: 20,
            ..Default::default()
        },
        finetune: TrainCfg {
            steps: 150,
            lr: 0.02,
            log_every: 20,
            ..Default::default()
        },
        ..Default::default()
    };
    let (pruned, rep) = train_prune_finetune(model.clone(), &ds, &cfg)?;
    println!("loss curve (train + finetune):");
    for e in &rep.loss_history {
        println!("  step {:>4}  loss {:.4}  lr {:.4}", e.step, e.loss, e.lr);
    }
    pruned.validate()?;

    println!("\n=== phase 2: OBSPA train-prune (no finetuning), same base ===");
    let mut obspa_cfg = cfg.clone();
    obspa_cfg.train.log_every = 0;
    let (_, obspa_rep) = train_prune(
        model,
        &ds,
        None,
        NoFinetuneAlgo::Obspa(CalibSource::InDistribution),
        1.5,
        &obspa_cfg,
    )?;

    let mut t = Table::new(
        "end-to-end results (SynthCIFAR-10, resnet18-mini)",
        &["pipeline", "ori acc.", "pruned acc.", "final acc.", "RF", "RP", "secs"],
    );
    for (name, r) in [("SPA-L1 + finetune", &rep), ("OBSPA (ID), no finetune", &obspa_rep)] {
        t.row(&[
            name.to_string(),
            format!("{:.2}%", r.ori_acc * 100.0),
            format!("{:.2}%", r.pruned_acc * 100.0),
            format!("{:.2}%", r.final_acc * 100.0),
            format!("{:.2}x", r.rf),
            format!("{:.2}x", r.rp),
            format!("{:.1}", r.seconds),
        ]);
    }
    t.print();
    Ok(())
}
