//! Transformer pruning on text (paper §4.3 / Fig. 4): train a
//! DistilBERT-mini on synthetic SST-2, then compare OBSPA against L1
//! one-shot pruning without fine-tuning across compression ratios.
//!
//! ```bash
//! cargo run --release --example text_pruning
//! ```

use spa::analysis;
use spa::criteria::Criterion;
use spa::data::TextDataset;
use spa::obspa::{self, ObspaCfg};
use spa::train::{self, TrainCfg};
use spa::util::Table;
use spa::zoo::{self, TextCfg};
use spa::{Session, Target};

fn main() -> anyhow::Result<()> {
    let tcfg = TextCfg::default();
    let ds = TextDataset::synth_sst(2, 1024, tcfg.seq, tcfg.vocab, 31);
    let mut base = zoo::distilbert(tcfg, 5);
    println!("training distilbert-mini ({} params) ...", base.num_params());
    train::train(
        &mut base,
        &ds,
        &TrainCfg {
            steps: 250,
            lr: 0.05,
            log_every: 50,
            ..Default::default()
        },
    )?;
    let base_acc = train::evaluate_text(&base, &ds, 256)?;
    println!("base accuracy {:.2}%", base_acc * 100.0);

    let mut t = Table::new(
        "DistilBERT-mini / SynthSST-2, prune without fine-tuning",
        &["method", "target RF", "RF", "RP", "acc."],
    );
    for &rf in &[1.2f64, 1.4, 1.7] {
        // L1 one-shot (no weight update)
        let pruned = Session::on(&base)
            .criterion(Criterion::L1)
            .min_keep(2)
            .target(Target::FlopsRf(rf))
            .plan()?
            .apply()?;
        let acc = train::evaluate_text(&pruned.graph, &ds, 256)?;
        t.row(&[
            "L1 one-shot".into(),
            format!("{rf:.1}"),
            format!("{:.2}x", pruned.report.rf),
            format!("{:.2}x", pruned.report.rp),
            format!("{:.2}%", acc * 100.0),
        ]);
        // OBSPA (OOD text calibration: a different token distribution)
        let mut g = base.clone();
        let ood = TextDataset::synth_sst(4, 256, tcfg.seq, tcfg.vocab, 77);
        let (calib, _) = ood.train_batch_seeded(9, 64);
        obspa::obspa_prune(
            &mut g,
            &calib,
            &ObspaCfg {
                target_rf: rf,
                min_keep: 2,
                bn_recalibrate: false, // transformer: LayerNorm only
                ..Default::default()
            },
        )?;
        let r = analysis::reduction(&base, &g);
        let acc = train::evaluate_text(&g, &ds, 256)?;
        t.row(&[
            "OBSPA (OOD)".into(),
            format!("{rf:.1}"),
            format!("{:.2}x", r.rf),
            format!("{:.2}x", r.rp),
            format!("{:.2}%", acc * 100.0),
        ]);
    }
    t.print();
    println!("expected shape (paper Fig. 4): OBSPA dominates L1 one-shot at equal RF");
    Ok(())
}
