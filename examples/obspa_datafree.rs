//! OBSPA in all three calibration regimes (paper Tab. 4): In-Distribution,
//! Out-Of-Distribution, and fully DataFree (uniform noise), against the
//! DFPC-style data-free baseline — pruning without any fine-tuning.
//!
//! ```bash
//! cargo run --release --example obspa_datafree
//! ```

use spa::coordinator::{train_prune, NoFinetuneAlgo, PipelineCfg};
use spa::data::ImageDataset;
use spa::obspa::CalibSource;
use spa::train::TrainCfg;
use spa::util::Table;
use spa::zoo::{self, ImageCfg};

fn main() -> anyhow::Result<()> {
    let icfg = ImageCfg {
        hw: 8,
        classes: 10,
        ..Default::default()
    };
    let ds = ImageDataset::synth_cifar(10, 1024, icfg.hw, icfg.channels, 555);
    // OOD: a different synthetic distribution (the CIFAR-100 stand-in)
    let ood = ImageDataset::synth_cifar(20, 512, icfg.hw, icfg.channels, 777);
    let cfg = PipelineCfg {
        train: TrainCfg {
            steps: 250,
            lr: 0.05,
            log_every: 0,
            ..Default::default()
        },
        ..Default::default()
    };
    let target_rf = 1.5;
    let mut t = Table::new(
        "OBSPA vs DFPC without fine-tuning (resnet50-mini / SynthCIFAR-10)",
        &["method", "ori acc.", "acc. drop", "RF", "RP"],
    );
    let runs: Vec<(&str, NoFinetuneAlgo)> = vec![
        ("DFPC (baseline)", NoFinetuneAlgo::Dfpc),
        ("OBSPA (ID)", NoFinetuneAlgo::Obspa(CalibSource::InDistribution)),
        ("OBSPA (OOD)", NoFinetuneAlgo::Obspa(CalibSource::OutOfDistribution)),
        ("OBSPA (DataFree)", NoFinetuneAlgo::Obspa(CalibSource::DataFree)),
    ];
    for (name, algo) in runs {
        let g = zoo::resnet50(icfg, 11);
        let (_, rep) = train_prune(g, &ds, Some(&ood), algo, target_rf, &cfg)?;
        t.row(&[
            name.to_string(),
            format!("{:.2}%", rep.ori_acc * 100.0),
            format!("{:+.2}%", (rep.final_acc - rep.ori_acc) * 100.0),
            format!("{:.2}x", rep.rf),
            format!("{:.2}x", rep.rp),
        ]);
    }
    t.print();
    println!("expected shape (paper Tab. 4): OBSPA drops ≪ DFPC; ID ≤ OOD ≤ DataFree drops");
    Ok(())
}
