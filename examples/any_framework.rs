//! "Prune Any Framework" (paper §4.1, Tab. 1): the same ResNet-18-mini
//! expressed in four framework dialects — torch-like NCHW, tf-like NHWC
//! with fused conv-bias, flax/jax-like, mxnet-like — each imported into
//! SPA-IR, pruned by the identical pipeline, and verified numerically
//! against the source model.
//!
//! ```bash
//! cargo run --release --example any_framework
//! ```

use spa::criteria::Criterion;
use spa::engine;
use spa::frontends::{export_model, import_model, Dialect};
use spa::tensor::Tensor;
use spa::util::{time_once, Rng, Table};
use spa::zoo::{self, ImageCfg};
use spa::{Session, Target};

fn main() -> anyhow::Result<()> {
    let cfg = ImageCfg {
        hw: 8,
        ..Default::default()
    };
    let source = zoo::resnet18(cfg, 99);
    let mut rng = Rng::new(3);
    let x = Tensor::new(
        vec![2, cfg.channels, cfg.hw, cfg.hw],
        rng.uniform_vec(2 * cfg.channels * cfg.hw * cfg.hw, -1.0, 1.0),
    );
    let reference = engine::predict(&source, x.clone())?;

    let mut t = Table::new(
        "framework funnel (resnet18-mini)",
        &["dialect", "convert (ms)", "max |Δlogit|", "RF after prune", "status"],
    );
    for d in Dialect::ALL {
        // export in the framework's own idiom, then import (normalize)
        let (doc, secs) = time_once(|| export_model(&source, d));
        let (g, secs2) = time_once(|| import_model(&doc).unwrap());
        let y = engine::predict(&g, x.clone())?;
        let delta = y
            .data
            .iter()
            .zip(&reference.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // identical pruning pipeline regardless of origin
        let pruned = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(2.0))
            .plan()?
            .apply()?;
        t.row(&[
            d.name().to_string(),
            format!("{:.1}", (secs + secs2) * 1e3),
            format!("{delta:.2e}"),
            format!("{:.2}x", pruned.report.rf),
            "pruned + valid".to_string(),
        ]);
    }
    t.print();
    Ok(())
}
