//! Quickstart: build a model, discover its coupled-channel groups, prune
//! it ~2× with grouped L1 (SPA-L1), and run the pruned model — the four
//! steps of paper §3.2 in ~40 lines of user code.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use spa::analysis;
use spa::engine;
use spa::prune::{self, build_groups, score_groups, Agg, Norm};
use spa::tensor::Tensor;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    // 1. Any architecture: a ResNet-18-mini from the zoo (swap for any
    //    other `zoo::by_name` model — the code below does not change).
    let cfg = ImageCfg::default();
    let mut model = zoo::resnet18(cfg, 42);
    println!(
        "model {}: {} params, {} FLOPs",
        model.name,
        model.num_params(),
        analysis::flops(&model)
    );

    // 2. Coupling + grouping: mask propagation discovers every coupled
    //    channel automatically (residuals, downsamples, BN params, ...).
    let groups = build_groups(&model)?;
    println!(
        "discovered {} groups / {} prunable coupled-channel sets",
        groups.groups.len(),
        groups.num_prunable_ccs()
    );

    // 3. Importance: grouped L1 (Eq. 1 with S = |θ|, AGG = Σ, Norm = mean).
    let mut l1 = HashMap::new();
    for pid in model.param_ids() {
        l1.insert(pid, model.data(pid).param().unwrap().map(f32::abs));
    }
    let scores = score_groups(&model, &groups, &l1, Agg::Sum, Norm::Mean);

    // 4. Prune to a 2× FLOPs reduction and verify the model still runs.
    let dense = model.clone();
    let sel = prune::select_by_flops_target(&model, &groups, &scores, 2.0, 1)?;
    prune::apply_pruning(&mut model, &groups, &sel)?;
    let r = analysis::reduction(&dense, &model);
    println!("pruned {} coupled sets: RF {:.2}x RP {:.2}x", sel.len(), r.rf, r.rp);

    let mut rng = Rng::new(7);
    let x = Tensor::new(
        vec![2, cfg.channels, cfg.hw, cfg.hw],
        rng.uniform_vec(2 * cfg.channels * cfg.hw * cfg.hw, -1.0, 1.0),
    );
    let logits = engine::predict(&model, x)?;
    println!("pruned model logits shape {:?} — OK", logits.shape);
    Ok(())
}
