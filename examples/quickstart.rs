//! Quickstart: build a model, plan a ~2× grouped-L1 prune (SPA-L1)
//! through the staged `Session` API, inspect the plan, apply it, and
//! serve the pruned model through a compiled execution plan — the four
//! steps of paper §3.2 plus deployment in ~30 lines of user code.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use spa::analysis;
use spa::criteria::Criterion;
use spa::engine;
use spa::tensor::Tensor;
use spa::util::Rng;
use spa::zoo::{self, ImageCfg};
use spa::{Session, Target};

fn main() -> anyhow::Result<()> {
    // 1. Any architecture: a ResNet-18-mini from the zoo (swap for any
    //    other `zoo::by_name` model — the code below does not change).
    let cfg = ImageCfg::default();
    let model = zoo::resnet18(cfg, 42);
    println!(
        "model {}: {} params, {} FLOPs",
        model.name,
        model.num_params(),
        analysis::flops(&model)
    );

    // 2+3. Coupling, grouping, and importance in one staged call:
    //    grouped L1 (Eq. 1 with S = |θ|, AGG = Σ, Norm = mean — the
    //    session defaults), selecting toward a 2× FLOPs reduction.
    let plan = Session::on(&model)
        .criterion(Criterion::L1)
        .target(Target::FlopsRf(2.0))
        .plan()?;
    println!(
        "discovered {} groups / {} prunable coupled-channel sets",
        plan.num_groups(),
        plan.num_prunable_ccs()
    );

    // 4. The plan is inspectable (scores, selection, predicted RF/RP)
    //    before anything is deleted; `apply` prunes a clone.
    let pruned = plan.apply()?;
    println!(
        "pruned {} coupled sets: RF {:.2}x RP {:.2}x",
        pruned.report.ccs_removed, pruned.report.rf, pruned.report.rp
    );

    // 5. Serving: compile the pruned graph once into an execution plan
    //    (buffer arena + fused kernels, bit-identical to the
    //    interpreter), then run it as many times as traffic demands.
    let compiled = pruned.compile()?;
    let rep = compiled.report();
    println!(
        "compiled plan: {} steps ({} fused), {} arena bytes vs {} interpreted",
        rep.steps, rep.fused_ops, rep.peak_arena_bytes, rep.interp_intermediate_bytes
    );
    let mut runner = compiled.runner();
    let mut rng = Rng::new(7);
    let x = Tensor::new(
        vec![2, cfg.channels, cfg.hw, cfg.hw],
        rng.uniform_vec(2 * cfg.channels * cfg.hw * cfg.hw, -1.0, 1.0),
    );
    let logits = runner.predict(&x)?;
    let reference = engine::predict(&pruned.graph, x.clone())?;
    assert_eq!(logits.data, reference.data, "plan must match the interpreter");
    println!("pruned model logits shape {:?} — OK (plan == interpreter)", logits.shape);

    // 6. Any traffic: the same plans serve over TCP with dynamic
    //    batching (`spa serve` on the CLI). Five lines of client code:
    let server = spa::serve::Server::spawn(spa::serve::ServeCfg::default())?;
    let mut client = spa::serve::Client::connect(server.local_addr())?;
    let (served, latency_us) = client.predict("resnet18", &x)?;
    println!("served logits {:?} in {latency_us}us (batched over TCP)", served.shape);
    server.shutdown();
    Ok(())
}
